//! Hub wire protocol: length-prefixed JSON frames + the tuned-entry
//! merge rule.
//!
//! A frame on the wire is a 4-byte big-endian length followed by that
//! many bytes of UTF-8 JSON (one object with a `"type"` tag). JSON keeps
//! the protocol debuggable (`socat` a hub and read it) and reuses
//! [`crate::util::json`] — the hub adds no dependencies.
//!
//! Entries carry a **per-entry monotonic version**. Merging is
//! last-writer-wins-by-version: a newer version replaces, an identical
//! payload at any version is a no-op, an *equal*-version race between
//! two writers is tie-broken by arrival (the later writer is promoted
//! one version up, so every accepted write remains monotonic and
//! pullers can detect it), and a strictly *older* version with a
//! different payload is rejected as stale knowledge.

use std::collections::BTreeMap;
use std::io::{Read, Write};

use crate::autotuner::ProblemKey;
use crate::error::{Error, Result};
use crate::util::json::{n, s, Value};

/// Protocol version spoken by this build; bumped on incompatible frame
/// changes. Exchanged in `Hello`/`HelloAck`.
pub const PROTOCOL_VERSION: i64 = 1;

/// Upper bound on one frame's body — a tuned map is a few KB per entry,
/// so anything near this is a corrupt length prefix, not a real frame.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// One tuned winner as shared through the hub (and written by
/// `save_state` / read by `state merge`, minus the version which state
/// files may omit — it defaults to 0 and is normalized to 1 on merge).
#[derive(Debug, Clone, PartialEq)]
pub struct HubEntry {
    /// Kernel family name.
    pub kernel: String,
    /// Autotune-parameter name.
    pub param: String,
    /// Argument signature, e.g. `f32[128,128],f32[128,128]`.
    pub signature: String,
    /// Candidate parameter values in declaration order (adoption is
    /// refused when these no longer match the local manifest).
    pub values: Vec<i64>,
    /// The winning parameter value.
    pub winner_value: i64,
    /// Monotonic per-entry version; higher wins a merge.
    pub version: u64,
}

/// Merge identity: the tuning problem *plus* its candidate-value set.
/// Two binary flavors that disagree on the candidate grid for the same
/// problem are distinct entries — they version independently instead of
/// clobbering each other's slot (the hub serves heterogeneous fleets).
pub type EntryKey = (ProblemKey, Vec<i64>);

impl HubEntry {
    /// Tuning-problem identity of this entry (display / adoption).
    pub fn problem_key(&self) -> ProblemKey {
        ProblemKey::new(&self.kernel, &self.param, &self.signature)
    }

    /// Merge identity of this entry (problem + candidate grid).
    pub fn entry_key(&self) -> EntryKey {
        (self.problem_key(), self.values.clone())
    }

    /// Whether two entries describe the same tuning result (version
    /// excluded — it orders writes, it is not part of the payload).
    pub fn same_payload(&self, other: &HubEntry) -> bool {
        self.winner_value == other.winner_value && self.values == other.values
    }

    /// Serialize to the state-file/wire object shape.
    pub fn to_json(&self) -> Value {
        Value::Obj(vec![
            ("kernel".into(), s(self.kernel.clone())),
            ("param".into(), s(self.param.clone())),
            ("signature".into(), s(self.signature.clone())),
            ("values".into(), Value::Arr(self.values.iter().map(|&v| n(v as f64)).collect())),
            ("winner_value".into(), n(self.winner_value as f64)),
            ("version".into(), n(self.version as f64)),
        ])
    }

    /// Parse from the state-file/wire object shape. `version` is
    /// optional (plain `save_state` files carry none) and defaults to 0.
    pub fn from_json(v: &Value) -> Result<HubEntry> {
        let values: Vec<i64> = v
            .req_arr("values")?
            .iter()
            .map(|x| {
                x.as_i64().ok_or_else(|| {
                    Error::Autotune("hub entry: non-integer candidate value".into())
                })
            })
            .collect::<Result<_>>()?;
        let version = v.get("version").and_then(Value::as_i64).unwrap_or(0).max(0) as u64;
        Ok(HubEntry {
            kernel: v.req_str("kernel")?.to_string(),
            param: v.req_str("param")?.to_string(),
            signature: v.req_str("signature")?.to_string(),
            values,
            winner_value: v.req_i64("winner_value")?,
            version,
        })
    }
}

/// Outcome of merging one incoming entry into a tuned map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Merge {
    /// First entry for this problem.
    Inserted,
    /// Strictly newer version replaced the stored entry.
    Replaced,
    /// Older/equal version, identical payload — idempotent republish.
    Stale,
    /// *Equal* version with a different payload — two writers raced the
    /// same version: the later arrival won and was re-versioned to
    /// `assigned`.
    Conflict {
        /// Version the incoming entry was promoted to.
        assigned: u64,
    },
    /// Strictly *older* version with a different payload: the incoming
    /// entry is stale knowledge and was rejected — the stored, newer
    /// entry stands.
    Outdated,
}

/// Merge `entry` into `map` under last-writer-wins-by-version: a higher
/// version always wins, a strictly lower version always loses, and an
/// equal-version race is tie-broken by arrival (the later writer is
/// promoted one version up). `Stale`/`Outdated` leave the map
/// untouched; every other outcome stores `entry` with a version
/// strictly above whatever it replaced. A version of 0 (an unversioned
/// state file) is normalized to 1.
pub fn merge_entry(map: &mut BTreeMap<EntryKey, HubEntry>, mut entry: HubEntry) -> Merge {
    if entry.version == 0 {
        entry.version = 1;
    }
    let key = entry.entry_key();
    match map.get(&key) {
        None => {
            map.insert(key, entry);
            Merge::Inserted
        }
        Some(cur) if entry.version > cur.version => {
            map.insert(key, entry);
            Merge::Replaced
        }
        Some(cur) if cur.same_payload(&entry) => Merge::Stale,
        Some(cur) if entry.version == cur.version => {
            let assigned = cur.version + 1;
            entry.version = assigned;
            map.insert(key, entry);
            Merge::Conflict { assigned }
        }
        Some(_) => Merge::Outdated,
    }
}

/// Marker string identifying a `jitune state export` cache artifact.
pub const ARTIFACT_KIND: &str = "jitune-tuned-cache";

/// Artifact format version this build writes (and the newest it reads).
pub const ARTIFACT_FORMAT: i64 = 1;

/// Wrap a tuned map into the deployable cache-artifact object that
/// `jitune state export` writes: versioned entries under a typed
/// envelope, so an import can tell a shipped cache from an arbitrary
/// JSON file.
pub fn artifact_json(entries: &[HubEntry]) -> Value {
    Value::Obj(vec![
        ("artifact".into(), s(ARTIFACT_KIND)),
        ("format".into(), n(ARTIFACT_FORMAT as f64)),
        ("entries".into(), Value::Arr(entries.iter().map(HubEntry::to_json).collect())),
    ])
}

/// The entry array of a tuned-state document, whichever shape it is: a
/// bare JSON array (`save_state` output) or a `jitune state export`
/// artifact object. Everything that reads tuned state — `load_state`,
/// `state merge`, `state import` — accepts both, so a shipped cache
/// artifact is usable anywhere a state file is.
pub fn state_entry_values(doc: &Value) -> Result<&[Value]> {
    if let Some(arr) = doc.as_arr() {
        return Ok(arr);
    }
    match doc.get("artifact").and_then(Value::as_str) {
        Some(ARTIFACT_KIND) => {
            let format = doc.get("format").and_then(Value::as_i64).unwrap_or(ARTIFACT_FORMAT);
            if format > ARTIFACT_FORMAT {
                return Err(proto_err(format!(
                    "cache artifact format {format} is newer than this build reads \
                     ({ARTIFACT_FORMAT}); upgrade jitune"
                )));
            }
            doc.req_arr("entries")
        }
        Some(kind) => Err(proto_err(format!("unknown artifact kind `{kind}`"))),
        None => Err(Error::Autotune(
            "state file: expected a JSON array or a jitune-tuned-cache artifact".into(),
        )),
    }
}

/// One protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server greeting.
    Hello {
        /// Speaker's protocol version.
        protocol: i64,
        /// Human-readable peer name (diagnostics only).
        peer: String,
    },
    /// Server → client greeting reply.
    HelloAck {
        /// Server's protocol version.
        protocol: i64,
        /// Entries currently held.
        entries: i64,
    },
    /// Client → server: send me the full tuned map.
    PullAll,
    /// Server → client: the full tuned map.
    Update {
        /// Every entry the hub holds.
        entries: Vec<HubEntry>,
    },
    /// Client → server: merge this winner.
    Publish {
        /// The entry to merge.
        entry: HubEntry,
    },
    /// Server → client: publish outcome.
    Ack {
        /// Version the entry is stored under (echoes the published
        /// version, or the re-assigned one on conflict).
        version: u64,
        /// Whether the merge was a version conflict.
        conflict: bool,
    },
    /// Client → server: turn this connection into a push channel. After
    /// the server replies [`Frame::Subscribed`], every accepted publish
    /// is pushed to it as an [`Frame::Update`] — no polling.
    Subscribe {
        /// Human-readable peer name (diagnostics only).
        peer: String,
    },
    /// Server → client: subscription accepted; carries the full tuned
    /// map so the subscriber starts synchronized (pushes only cover
    /// publishes *after* this point).
    Subscribed {
        /// Every entry the hub holds at subscription time.
        entries: Vec<HubEntry>,
    },
}

impl Frame {
    fn to_json(&self) -> Value {
        match self {
            Frame::Hello { protocol, peer } => Value::Obj(vec![
                ("type".into(), s("hello")),
                ("protocol".into(), n(*protocol as f64)),
                ("peer".into(), s(peer.clone())),
            ]),
            Frame::HelloAck { protocol, entries } => Value::Obj(vec![
                ("type".into(), s("hello_ack")),
                ("protocol".into(), n(*protocol as f64)),
                ("entries".into(), n(*entries as f64)),
            ]),
            Frame::PullAll => Value::Obj(vec![("type".into(), s("pull_all"))]),
            Frame::Update { entries } => Value::Obj(vec![
                ("type".into(), s("update")),
                ("entries".into(), Value::Arr(entries.iter().map(HubEntry::to_json).collect())),
            ]),
            Frame::Publish { entry } => Value::Obj(vec![
                ("type".into(), s("publish")),
                ("entry".into(), entry.to_json()),
            ]),
            Frame::Ack { version, conflict } => Value::Obj(vec![
                ("type".into(), s("ack")),
                ("version".into(), n(*version as f64)),
                ("conflict".into(), Value::Bool(*conflict)),
            ]),
            Frame::Subscribe { peer } => Value::Obj(vec![
                ("type".into(), s("subscribe")),
                ("peer".into(), s(peer.clone())),
            ]),
            Frame::Subscribed { entries } => Value::Obj(vec![
                ("type".into(), s("subscribed")),
                ("entries".into(), Value::Arr(entries.iter().map(HubEntry::to_json).collect())),
            ]),
        }
    }

    fn from_json(v: &Value) -> Result<Frame> {
        let kind = v.req_str("type").map_err(|_| proto_err("frame without `type`"))?;
        match kind {
            "hello" => Ok(Frame::Hello {
                protocol: v.req_i64("protocol")?,
                peer: v.req_str("peer")?.to_string(),
            }),
            "hello_ack" => Ok(Frame::HelloAck {
                protocol: v.req_i64("protocol")?,
                entries: v.req_i64("entries")?,
            }),
            "pull_all" => Ok(Frame::PullAll),
            "update" => Ok(Frame::Update {
                entries: v
                    .req_arr("entries")?
                    .iter()
                    .map(HubEntry::from_json)
                    .collect::<Result<_>>()?,
            }),
            "publish" => Ok(Frame::Publish {
                entry: HubEntry::from_json(
                    v.get("entry").ok_or_else(|| proto_err("publish without `entry`"))?,
                )?,
            }),
            "ack" => Ok(Frame::Ack {
                version: v.req_i64("version")?.max(0) as u64,
                conflict: v.get("conflict").and_then(Value::as_bool).unwrap_or(false),
            }),
            "subscribe" => Ok(Frame::Subscribe { peer: v.req_str("peer")?.to_string() }),
            "subscribed" => Ok(Frame::Subscribed {
                entries: v
                    .req_arr("entries")?
                    .iter()
                    .map(HubEntry::from_json)
                    .collect::<Result<_>>()?,
            }),
            other => Err(proto_err(format!("unknown frame type `{other}`"))),
        }
    }
}

/// Protocol-level error (framing, unexpected frame).
pub(crate) fn proto_err(msg: impl Into<String>) -> Error {
    Error::Coordinator(format!("hub: {}", msg.into()))
}

/// Socket io failure — kept as [`Error::Io`] so callers can inspect the
/// [`std::io::ErrorKind`] (the client treats timeouts differently from
/// dead connections).
fn io_err(op: &str, e: std::io::Error) -> Error {
    Error::io(format!("hub socket ({op})"), e)
}

/// Write one frame: 4-byte big-endian length prefix + JSON body.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let body = frame.to_json().to_json();
    let bytes = body.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(proto_err(format!("frame too large ({} bytes)", bytes.len())));
    }
    let len = (bytes.len() as u32).to_be_bytes();
    w.write_all(&len).map_err(|e| io_err("write", e))?;
    w.write_all(bytes).map_err(|e| io_err("write", e))?;
    w.flush().map_err(|e| io_err("flush", e))?;
    Ok(())
}

/// Read one frame (blocking). An EOF before the length prefix surfaces
/// as an error — servers treat it as a clean disconnect.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).map_err(|e| io_err("read", e))?;
    let len = u32::from_be_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(proto_err(format!("bad frame length {len}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| io_err("read", e))?;
    let text = std::str::from_utf8(&body).map_err(|_| proto_err("frame body is not UTF-8"))?;
    Frame::from_json(&crate::util::json::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(kernel: &str, winner: i64, version: u64) -> HubEntry {
        HubEntry {
            kernel: kernel.into(),
            param: "p".into(),
            signature: "f32[8,8]".into(),
            values: vec![0, 1],
            winner_value: winner,
            version,
        }
    }

    #[test]
    fn frames_roundtrip_through_a_byte_stream() {
        let frames = vec![
            Frame::Hello { protocol: PROTOCOL_VERSION, peer: "worker-1".into() },
            Frame::HelloAck { protocol: PROTOCOL_VERSION, entries: 2 },
            Frame::PullAll,
            Frame::Update { entries: vec![entry("a", 1, 3), entry("b", 0, 1)] },
            Frame::Publish { entry: entry("c", 1, 7) },
            Frame::Ack { version: 7, conflict: true },
            Frame::Subscribe { peer: "replica-2".into() },
            Frame::Subscribed { entries: vec![entry("a", 1, 3)] },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        // stream fully consumed; another read is a clean EOF error
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn entry_roundtrips_and_tolerates_missing_version() {
        let e = entry("k", 1, 5);
        assert_eq!(HubEntry::from_json(&e.to_json()).unwrap(), e);
        // a plain save_state entry has no version field → 0
        let text = r#"{"kernel":"k","param":"p","signature":"f32[8,8]",
                       "values":[0,1],"winner_value":1}"#;
        let parsed = HubEntry::from_json(&crate::util::json::parse(text).unwrap()).unwrap();
        assert_eq!(parsed.version, 0);
        assert!(parsed.same_payload(&e));
    }

    #[test]
    fn entry_with_tricky_key_strings_survives_the_wire() {
        // problem keys are arbitrary strings: escapes must round-trip
        let e = HubEntry {
            kernel: "kern \"q\" \\ \n\t中😀".into(),
            param: "p\u{01}".into(),
            signature: "f32[8,8],f32[8,8]".into(),
            values: vec![1, 2, 3],
            winner_value: 2,
            version: 1,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Publish { entry: e.clone() }).unwrap();
        match read_frame(&mut &buf[..]).unwrap() {
            Frame::Publish { entry } => assert_eq!(entry, e),
            f => panic!("unexpected {f:?}"),
        }
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        // garbage length prefix
        let mut r: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0x00];
        assert!(read_frame(&mut r).is_err());
        // zero length
        let mut r: &[u8] = &[0, 0, 0, 0];
        assert!(read_frame(&mut r).is_err());
        // valid prefix, invalid JSON
        let mut buf = 3u32.to_be_bytes().to_vec();
        buf.extend_from_slice(b"{{{");
        assert!(read_frame(&mut &buf[..]).is_err());
        // valid JSON, unknown type
        let body = br#"{"type":"nope"}"#;
        let mut buf = (body.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(body);
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn state_documents_may_be_arrays_or_artifacts() {
        let entries = vec![entry("a", 1, 3), entry("b", 0, 1)];
        // artifact object: the envelope unwraps to its entries
        let doc = artifact_json(&entries);
        let values = state_entry_values(&doc).unwrap();
        let parsed: Vec<HubEntry> =
            values.iter().map(|v| HubEntry::from_json(v).unwrap()).collect();
        assert_eq!(parsed, entries);
        // bare array (plain save_state output) passes through untouched
        let bare = Value::Arr(entries.iter().map(HubEntry::to_json).collect());
        assert_eq!(state_entry_values(&bare).unwrap().len(), 2);
        // a future format is refused rather than misread
        let future = crate::util::json::parse(
            r#"{"artifact":"jitune-tuned-cache","format":99,"entries":[]}"#,
        )
        .unwrap();
        assert!(state_entry_values(&future).is_err());
        // a different artifact kind is refused
        let alien =
            crate::util::json::parse(r#"{"artifact":"something-else","entries":[]}"#).unwrap();
        assert!(state_entry_values(&alien).is_err());
        // an arbitrary object is not a state document
        let junk = crate::util::json::parse(r#"{"entries":[]}"#).unwrap();
        assert!(state_entry_values(&junk).is_err());
    }

    #[test]
    fn merge_is_last_writer_wins_by_version() {
        let mut map = BTreeMap::new();
        assert_eq!(merge_entry(&mut map, entry("k", 0, 1)), Merge::Inserted);
        // newer version replaces
        assert_eq!(merge_entry(&mut map, entry("k", 1, 2)), Merge::Replaced);
        assert_eq!(map.values().next().unwrap().winner_value, 1);
        // idempotent republish of the same payload at an old version
        assert_eq!(merge_entry(&mut map, entry("k", 1, 1)), Merge::Stale);
        assert_eq!(map.values().next().unwrap().version, 2);
        // same version, different payload: later writer wins, re-versioned
        assert_eq!(merge_entry(&mut map, entry("k", 0, 2)), Merge::Conflict { assigned: 3 });
        let stored = map.values().next().unwrap();
        assert_eq!((stored.winner_value, stored.version), (0, 3));
        // strictly older version, different payload: stale knowledge
        // loses — a peer re-asserting a superseded winner cannot
        // clobber the newer one
        assert_eq!(merge_entry(&mut map, entry("k", 1, 2)), Merge::Outdated);
        let stored = map.values().next().unwrap();
        assert_eq!((stored.winner_value, stored.version), (0, 3));
    }

    #[test]
    fn different_candidate_sets_are_distinct_entries() {
        // heterogeneous fleet: two binary flavors with different
        // candidate grids for the same problem must not clobber each
        // other's slot
        let mut map = BTreeMap::new();
        let a = entry("k", 0, 1); // values [0, 1]
        let mut b = entry("k", 2, 1);
        b.values = vec![0, 1, 2];
        assert_eq!(merge_entry(&mut map, a), Merge::Inserted);
        assert_eq!(merge_entry(&mut map, b), Merge::Inserted, "different grid, no conflict");
        assert_eq!(map.len(), 2);
    }

    #[test]
    fn merge_normalizes_unversioned_entries() {
        let mut map = BTreeMap::new();
        assert_eq!(merge_entry(&mut map, entry("k", 0, 0)), Merge::Inserted);
        assert_eq!(map.values().next().unwrap().version, 1);
        // distinct problems coexist
        assert_eq!(merge_entry(&mut map, entry("other", 1, 0)), Merge::Inserted);
        assert_eq!(map.len(), 2);
    }
}
