//! Pure-Rust reference implementations of every shipped kernel.
//!
//! These mirror `python/compile/kernels/ref.py` and serve as the
//! cross-language oracle: integration tests execute the AOT-lowered HLO
//! through PJRT and assert agreement with these functions.

use crate::error::{Error, Result};
use crate::tensor::HostTensor;

/// Naive triple-loop matmul: `C[M,N] = A[M,K] @ B[K,N]`.
///
/// f64 accumulation keeps the oracle more accurate than the f32 kernels it
/// checks, so tolerance failures indicate kernel bugs, not oracle noise.
pub fn ref_matmul(a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    let (ash, bsh) = (a.shape(), b.shape());
    if ash.len() != 2 || bsh.len() != 2 || ash[1] != bsh[0] {
        return Err(Error::ShapeMismatch {
            kernel: "ref_matmul".into(),
            expected: "A[M,K] x B[K,N]".into(),
            got: format!("{ash:?} x {bsh:?}"),
        });
    }
    let (m, k, n) = (ash[0], ash[1], bsh[1]);
    let mut c = HostTensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a.at2(i, p) as f64 * b.at2(p, j) as f64;
            }
            c.set2(i, j, acc as f32);
        }
    }
    Ok(c)
}

/// saxpy: `y' = a*x + y` (element-wise, any matching shapes).
pub fn ref_saxpy(a: f32, x: &HostTensor, y: &HostTensor) -> Result<HostTensor> {
    if x.shape() != y.shape() {
        return Err(Error::ShapeMismatch {
            kernel: "ref_saxpy".into(),
            expected: x.signature(),
            got: y.signature(),
        });
    }
    let data = x.data().iter().zip(y.data()).map(|(xv, yv)| a * xv + yv).collect();
    HostTensor::from_vec(x.shape(), data)
}

/// 3-point Jacobi stencil over a 1-D array with fixed (copied) boundaries:
/// `out[i] = (x[i-1] + x[i] + x[i+1]) / 3` for interior points.
pub fn ref_stencil3(x: &HostTensor) -> Result<HostTensor> {
    if x.shape().len() != 1 {
        return Err(Error::ShapeMismatch {
            kernel: "ref_stencil3".into(),
            expected: "rank-1".into(),
            got: x.signature(),
        });
    }
    let n = x.len();
    let src = x.data();
    let mut out = src.to_vec();
    for i in 1..n.saturating_sub(1) {
        out[i] = (src[i - 1] + src[i] + src[i + 1]) / 3.0;
    }
    HostTensor::from_vec(x.shape(), out)
}

/// ReLU.
pub fn ref_relu(x: &HostTensor) -> HostTensor {
    let data = x.data().iter().map(|&v| v.max(0.0)).collect();
    HostTensor::from_vec(x.shape(), data).expect("same shape")
}

/// The end-to-end example's MLP block: `relu(x @ w1) @ w2`.
pub fn ref_mlp_block(x: &HostTensor, w1: &HostTensor, w2: &HostTensor) -> Result<HostTensor> {
    let h = ref_relu(&ref_matmul(x, w1)?);
    ref_matmul(&h, w2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        let a = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::full(&[2, 2], 1.0);
        let c = ref_matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let n = 8;
        let mut eye = HostTensor::zeros(&[n, n]);
        for i in 0..n {
            eye.set2(i, i, 1.0);
        }
        let a = HostTensor::random(&[n, n], 1);
        let c = ref_matmul(&a, &eye).unwrap();
        assert!(c.allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_rejects_bad_shapes() {
        let a = HostTensor::zeros(&[2, 3]);
        let b = HostTensor::zeros(&[2, 3]);
        assert!(ref_matmul(&a, &b).is_err());
    }

    #[test]
    fn saxpy_values() {
        let x = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = HostTensor::from_vec(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        let r = ref_saxpy(2.0, &x, &y).unwrap();
        assert_eq!(r.data(), &[12.0, 24.0, 36.0]);
    }

    #[test]
    fn stencil_preserves_boundaries() {
        let x = HostTensor::from_vec(&[5], vec![3.0, 0.0, 3.0, 0.0, 3.0]).unwrap();
        let r = ref_stencil3(&x).unwrap();
        assert_eq!(r.data()[0], 3.0);
        assert_eq!(r.data()[4], 3.0);
        assert_eq!(r.data()[1], 2.0);
        assert_eq!(r.data()[2], 1.0);
    }

    #[test]
    fn relu_clamps() {
        let x = HostTensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -0.5]).unwrap();
        assert_eq!(ref_relu(&x).data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn mlp_block_composes() {
        let x = HostTensor::random(&[4, 8], 1);
        let w1 = HostTensor::random(&[8, 16], 2);
        let w2 = HostTensor::random(&[16, 4], 3);
        let out = ref_mlp_block(&x, &w1, &w2).unwrap();
        assert_eq!(out.shape(), &[4, 4]);
        // manual check of one element path: h = relu(x@w1)
        let h = ref_relu(&ref_matmul(&x, &w1).unwrap());
        let expect = ref_matmul(&h, &w2).unwrap();
        assert!(out.allclose(&expect, 0.0, 0.0));
    }
}
