//! Row-major f32 host tensor.

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// A dense row-major f32 tensor on the host.
///
/// This is deliberately minimal — the request path only needs to stage
/// buffers for PJRT, seed them reproducibly, and compare results.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl HostTensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let len = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; len] }
    }

    /// Tensor from existing data; the element count must match the shape.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(Error::ShapeMismatch {
                kernel: "HostTensor::from_vec".into(),
                expected: format!("{want} elements for shape {shape:?}"),
                got: format!("{} elements", data.len()),
            });
        }
        Ok(HostTensor { shape: shape.to_vec(), data })
    }

    /// Deterministically seeded uniform values in [-1, 1).
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let mut rng = Rng::seed(seed);
        let len = shape.iter().product();
        let data = (0..len).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        HostTensor { shape: shape.to_vec(), data }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], value: f32) -> Self {
        let len = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![value; len] }
    }

    /// Shape (row-major dims).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// 2-D accessor (row-major). Panics on rank ≠ 2 or OOB in debug.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D mutable accessor.
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Signature string like `f32[128,128]` — matches the manifest format.
    pub fn signature(&self) -> String {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        format!("f32[{}]", dims.join(","))
    }

    /// Max absolute element-wise difference; `None` on shape mismatch.
    pub fn max_abs_diff(&self, other: &HostTensor) -> Option<f32> {
        if self.shape != other.shape {
            return None;
        }
        Some(
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max),
        )
    }

    /// Relative allclose with atol+rtol (numpy semantics).
    pub fn allclose(&self, other: &HostTensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let z = HostTensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert!(z.data().iter().all(|&x| x == 0.0));
        let f = HostTensor::full(&[4], 2.5);
        assert!(f.data().iter().all(|&x| x == 2.5));
    }

    #[test]
    fn from_vec_checks_length() {
        assert!(HostTensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(HostTensor::from_vec(&[2, 2], vec![1.0; 5]).is_err());
    }

    #[test]
    fn random_is_seeded_and_bounded() {
        let a = HostTensor::random(&[100], 42);
        let b = HostTensor::random(&[100], 42);
        let c = HostTensor::random(&[100], 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data().iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn indexing_2d_row_major() {
        let mut t = HostTensor::zeros(&[2, 3]);
        t.set2(1, 2, 7.0);
        assert_eq!(t.at2(1, 2), 7.0);
        assert_eq!(t.data()[5], 7.0);
    }

    #[test]
    fn signature_format() {
        assert_eq!(HostTensor::zeros(&[128, 64]).signature(), "f32[128,64]");
        assert_eq!(HostTensor::zeros(&[5]).signature(), "f32[5]");
    }

    #[test]
    fn allclose_tolerances() {
        let a = HostTensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = HostTensor::from_vec(&[2], vec![1.0 + 1e-6, 2.0 - 1e-6]).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
        assert!(!a.allclose(&b, 0.0, 1e-8));
        let c = HostTensor::zeros(&[3]);
        assert!(!a.allclose(&c, 1.0, 1.0)); // shape mismatch
    }
}
