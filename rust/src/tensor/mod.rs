//! Host-side tensors and pure-Rust reference kernels.
//!
//! [`HostTensor`] is the coordinator's in-memory array type (row-major f32)
//! used to stage inputs for PJRT and read back outputs. The `ref_*`
//! functions are independent Rust implementations of every kernel the
//! Python layer ships — the cross-language correctness oracle: the HLO
//! executed through PJRT must agree with these to within float tolerance.

mod host;
mod reference;

pub use host::HostTensor;
pub use reference::{ref_matmul, ref_mlp_block, ref_relu, ref_saxpy, ref_stencil3};
