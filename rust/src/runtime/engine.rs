//! Engine abstraction: anything that can JIT-compile a variant and execute
//! it. Two implementations ship: [`crate::runtime::PjrtEngine`] (real PJRT
//! CPU client) and [`crate::runtime::mock::MockEngine`] (deterministic
//! latencies + failure injection for tests and ablations).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::manifest::Variant;
use crate::tensor::HostTensor;

/// A compiled kernel that may be executed from *any* thread — the handle
/// the coordinator's tuned fast lane publishes so steady-state calls can
/// run on the caller's thread without visiting the leader.
///
/// Split from [`CompiledKernel`] because not every backend can provide
/// one: PJRT executables are `Rc`-based and thread-pinned, so the PJRT
/// engine never offers a shared handle and its tuned calls keep flowing
/// through the leader.
pub trait SharedKernel: Send + Sync {
    /// Execute with host inputs, producing the kernel's (single) output.
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor>;

    /// Execute and report the *execution* duration — the quantity drift
    /// baselines are measured in. The default times `execute` on the
    /// calling thread (right for kernels that run in-place); handles
    /// that dispatch elsewhere (the worker pool) override it to return
    /// the backend-measured time, so queueing and cross-thread overhead
    /// cannot masquerade as kernel drift.
    fn execute_measured(&self, inputs: &[HostTensor]) -> Result<(HostTensor, Duration)> {
        let t0 = Instant::now();
        let output = self.execute(inputs)?;
        Ok((output, t0.elapsed()))
    }

    /// [`execute_measured`](SharedKernel::execute_measured) with an
    /// optional absolute deadline. The default ignores the deadline —
    /// kernels that run in-place on the calling thread cannot be
    /// interrupted mid-execution, so only the pre-call budget check in the
    /// fast lane applies. Handles that dispatch elsewhere (the worker
    /// pool) override this to bound the cross-thread wait and return
    /// [`Error::DeadlineExceeded`](crate::error::Error::DeadlineExceeded),
    /// leaving the worker-side result to be discarded on arrival.
    fn execute_measured_deadline(
        &self,
        inputs: &[HostTensor],
        _deadline: Option<Instant>,
    ) -> Result<(HostTensor, Duration)> {
        self.execute_measured(inputs)
    }

    /// Variant id this executable was compiled from.
    fn variant_id(&self) -> &str;
}

/// A compiled, executable kernel variant.
pub trait CompiledKernel {
    /// Execute with host inputs, producing the kernel's (single) output.
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor>;

    /// Variant id this executable was compiled from.
    fn variant_id(&self) -> &str;

    /// A `Send + Sync` handle to this executable for off-leader execution,
    /// when the backend supports one. Default: `None` (thread-pinned
    /// engines such as PJRT).
    fn shared(&self) -> Option<Arc<dyn SharedKernel>> {
        None
    }
}

/// Result of one engine execution plus the engine-side wall time (used by
/// benches; the autotuner applies its own [`crate::autotuner::Metric`]).
#[derive(Debug)]
pub struct ExecOutcome {
    /// Kernel output.
    pub output: HostTensor,
    /// Engine-measured execution duration.
    pub elapsed: Duration,
}

/// A JIT compilation + execution backend.
///
/// Deliberately `!Send`: the PJRT client is thread-pinned; the coordinator
/// owns the engine on its leader thread.
pub trait Engine {
    /// JIT-compile a variant from its HLO text. This is the run-time
    /// compilation step of the paper (cost *C* in Eq. 1).
    fn compile(&self, variant: &Variant, hlo_text: &str) -> Result<Box<dyn CompiledKernel>>;

    /// Backend name for logs/reports.
    fn name(&self) -> &str;
}

/// Builds engine instances on demand — one per worker thread of the
/// coordinator's worker pool ([`crate::coordinator::WorkerPool`]).
///
/// The factory itself crosses thread boundaries (`Send + Sync`), but the
/// engines it creates may be `!Send` (PJRT clients are thread-pinned):
/// `create` is therefore always invoked *on the thread that will own the
/// engine*, and the engine never leaves it. This is what lets a pool of
/// workers scale the tuned lane on backends whose executables cannot be
/// shared across threads — each worker owns a private engine and a
/// private compiled-kernel cache.
pub trait EngineFactory: Send + Sync {
    /// Create a fresh engine on the calling thread.
    fn create(&self) -> Result<Box<dyn Engine>>;

    /// Backend name for logs/stats.
    fn name(&self) -> &str;
}

#[cfg(test)]
mod tests {
    // Engine behaviour is exercised through MockEngine (runtime::mock) and
    // the PJRT integration tests (rust/tests/integration.rs).
}
