//! Run-time execution layer: PJRT client, JIT compile cache, engines.
//!
//! This is the analog of ClangJIT's runtime library: it owns the
//! instantiation cache and performs the actual just-in-time compilation
//! (PJRT `compile()` of an HLO-text artifact) the first time a variant is
//! needed.
//!
//! `xla::PjRtClient` is `Rc`-based and must stay on one thread; the
//! coordinator therefore runs the engine on a dedicated thread and feeds
//! it through channels ([`crate::coordinator::server`]). Everything here
//! is deliberately `!Send`.

mod compile;
mod engine;
pub mod mock;
mod pjrt;

pub use compile::{CacheStats, CompileCache};
pub use engine::{CompiledKernel, Engine, ExecOutcome};
pub use pjrt::PjrtEngine;
