//! Run-time execution layer: PJRT client, JIT compile cache, engines.
//!
//! This is the analog of ClangJIT's runtime library: it owns the
//! instantiation cache and performs the actual just-in-time compilation
//! (PJRT `compile()` of an HLO-text artifact) the first time a variant is
//! needed.
//!
//! `xla::PjRtClient` is `Rc`-based and must stay on one thread; the
//! coordinator therefore runs the engine on a dedicated thread and feeds
//! it through channels ([`crate::coordinator::server`]). Engines and the
//! cache are deliberately `!Send` — but an engine *may* hand out
//! [`SharedKernel`] handles (`Send + Sync`) for individual compiled
//! executables, which the coordinator's tuned fast lane publishes so
//! steady-state calls can execute on application threads. The mock and
//! native engines support this; PJRT does not (its executables are
//! `Rc`-based).
//! For backends like PJRT the [`EngineFactory`] trait closes the gap:
//! the coordinator's worker pool builds one engine per worker thread
//! (each client born on — and pinned to — its own worker) and replicates
//! finalized winners onto all of them, so tuned throughput scales with
//! workers without any executable crossing a thread.

mod compile;
mod engine;
pub mod mock;
pub mod native;
mod pjrt;

pub use compile::{CacheStats, CompileCache};
pub use engine::{CompiledKernel, Engine, EngineFactory, ExecOutcome, SharedKernel};
pub use native::{NativeEngine, NativeEngineFactory, NativeFault};
pub use pjrt::{PjrtEngine, PjrtEngineFactory};
