//! JIT compile cache — the analog of ClangJIT's instantiation cache.
//!
//! The first request for a variant reads its HLO text and JIT-compiles it
//! through the engine (the paper's run-time specialization, cost *C*);
//! subsequent requests hit the cache. ClangJIT guards this with a mutex so
//! no two threads compile the same instantiation concurrently; here the
//! cache lives on the single engine thread (PJRT is thread-pinned), which
//! serializes compilations by construction — the coordinator documents the
//! equivalent protocol at its channel boundary.
//!
//! Per the paper (§3.2 *Generating variants*), only the winning variant is
//! kept compiled after tuning: `evict` drops losing executables so memory
//! stays proportional to the number of *tuned* problems, not the whole
//! variant grid — we "can only keep ASTs" (HLO text) for the rest.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::manifest::{Manifest, Variant};
use crate::runtime::engine::{CompiledKernel, Engine, SharedKernel};

/// Aggregate cache statistics (exposed via coordinator stats and used by
/// the §Perf report).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Cache hits (no compilation needed).
    pub hits: u64,
    /// Cache misses (a JIT compilation was performed).
    pub misses: u64,
    /// Evicted executables (losing variants dropped after tuning).
    pub evictions: u64,
    /// Compilations that failed.
    pub failures: u64,
    /// Total time spent JIT-compiling.
    pub compile_time: Duration,
}

/// The instantiation cache: variant id → compiled executable.
pub struct CompileCache {
    engine: Box<dyn Engine>,
    cache: HashMap<String, Box<dyn CompiledKernel>>,
    /// HLO text cache: avoids re-reading artifacts on recompilation after
    /// eviction (the paper keeps ASTs in memory the same way).
    hlo_text: HashMap<String, String>,
    stats: CacheStats,
}

impl CompileCache {
    /// Wrap an engine with an empty cache.
    pub fn new(engine: Box<dyn Engine>) -> CompileCache {
        CompileCache { engine, cache: HashMap::new(), hlo_text: HashMap::new(), stats: CacheStats::default() }
    }

    /// Engine backing this cache.
    pub fn engine_name(&self) -> &str {
        self.engine.name()
    }

    /// Get the compiled executable for `variant`, JIT-compiling on miss.
    ///
    /// Returns whether this call compiled (`true` = miss) alongside the
    /// executable, so callers can attribute the compile cost (Fig 2 shows
    /// it per iteration).
    pub fn get_or_compile(
        &mut self,
        manifest: &Manifest,
        variant: &Variant,
    ) -> Result<(&dyn CompiledKernel, bool)> {
        // NOTE: written as two lookups (not entry()) because compilation
        // borrows `self` mutably for the text cache too.
        if self.cache.contains_key(&variant.id) {
            self.stats.hits += 1;
            return Ok((self.cache[&variant.id].as_ref(), false));
        }
        let text = self.load_hlo(manifest, variant)?;
        let t0 = Instant::now();
        let compiled = match self.engine.compile(variant, &text) {
            Ok(c) => c,
            Err(e) => {
                self.stats.failures += 1;
                return Err(e);
            }
        };
        self.stats.compile_time += t0.elapsed();
        self.stats.misses += 1;
        self.cache.insert(variant.id.clone(), compiled);
        Ok((self.cache[&variant.id].as_ref(), true))
    }

    /// Time one compilation explicitly (benches want the raw cost *C*).
    pub fn compile_timed(
        &mut self,
        manifest: &Manifest,
        variant: &Variant,
    ) -> Result<Duration> {
        self.evict(&variant.id);
        let t0 = Instant::now();
        self.get_or_compile(manifest, variant)?;
        Ok(t0.elapsed())
    }

    /// Drop a compiled variant (losing variants after tuning).
    pub fn evict(&mut self, variant_id: &str) {
        if self.cache.remove(variant_id).is_some() {
            self.stats.evictions += 1;
        }
    }

    /// Drop every compiled variant of `problem_key`'s kernel except
    /// `keep_id`. Called when tuning finalizes.
    pub fn evict_losers(&mut self, variant_ids: &[String], keep_id: &str) {
        for id in variant_ids {
            if id != keep_id {
                self.evict(id);
            }
        }
    }

    /// Whether a variant is currently compiled.
    pub fn contains(&self, variant_id: &str) -> bool {
        self.cache.contains_key(variant_id)
    }

    /// A `Send + Sync` handle to a resident executable, when the engine
    /// supports cross-thread execution (the mock does; PJRT does not).
    /// The coordinator's fast lane publishes this so steady-state calls
    /// run on the caller's thread.
    pub fn shared_handle(&self, variant_id: &str) -> Option<Arc<dyn SharedKernel>> {
        self.cache.get(variant_id).and_then(|k| k.shared())
    }

    /// The variant's HLO text (memoized), without compiling. The worker
    /// pool's replicated finalization broadcasts this so each
    /// thread-pinned engine compiles its own copy of the winner.
    pub fn hlo_for(&mut self, manifest: &Manifest, variant: &Variant) -> Result<String> {
        self.load_hlo(manifest, variant)
    }

    /// Number of resident executables.
    pub fn resident(&self) -> usize {
        self.cache.len()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    fn load_hlo(&mut self, manifest: &Manifest, variant: &Variant) -> Result<String> {
        if let Some(text) = self.hlo_text.get(&variant.id) {
            return Ok(text.clone());
        }
        let path = manifest.artifact_path(variant);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        self.hlo_text.insert(variant.id.clone(), text.clone());
        Ok(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{MockEngine, MockSpec};
    use std::path::PathBuf;

    fn setup() -> (Manifest, CompileCache) {
        let manifest = crate::manifest::tests::sample_manifest()
            .expect("sample manifest");
        let engine = MockEngine::new(MockSpec::default());
        (manifest, CompileCache::new(Box::new(engine)))
    }

    #[test]
    fn miss_then_hit() {
        let (m, mut cache) = setup();
        let v = m.variant("k.a.n8").unwrap().clone();
        let (_, compiled) = cache.get_or_compile(&m, &v).unwrap();
        assert!(compiled);
        let (_, compiled) = cache.get_or_compile(&m, &v).unwrap();
        assert!(!compiled);
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(cache.resident(), 1);
    }

    #[test]
    fn evict_losers_keeps_winner() {
        let (m, mut cache) = setup();
        let ids: Vec<String> = m.problem("k", 8).unwrap().variants.iter().map(|v| v.id.clone()).collect();
        for id in &ids {
            let v = m.variant(id).unwrap().clone();
            cache.get_or_compile(&m, &v).unwrap();
        }
        assert_eq!(cache.resident(), 2);
        cache.evict_losers(&ids, "k.a.n8");
        assert_eq!(cache.resident(), 1);
        assert!(cache.contains("k.a.n8"));
        assert!(!cache.contains("k.b.n8"));
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn recompile_after_evict_uses_text_cache() {
        let (m, mut cache) = setup();
        let v = m.variant("k.a.n8").unwrap().clone();
        cache.get_or_compile(&m, &v).unwrap();
        cache.evict(&v.id);
        let (_, compiled) = cache.get_or_compile(&m, &v).unwrap();
        assert!(compiled);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn shared_handle_for_resident_mock_kernels() {
        let (m, mut cache) = setup();
        let v = m.variant("k.a.n8").unwrap().clone();
        assert!(cache.shared_handle(&v.id).is_none(), "not compiled yet");
        cache.get_or_compile(&m, &v).unwrap();
        let shared = cache.shared_handle(&v.id).expect("mock kernels share");
        assert_eq!(shared.variant_id(), "k.a.n8");
        cache.evict(&v.id);
        assert!(cache.shared_handle(&v.id).is_none(), "evicted");
        // the handle obtained before eviction keeps working (Arc)
        assert!(shared.execute(&[]).is_ok());
    }

    #[test]
    fn compile_failure_counted() {
        let manifest = crate::manifest::tests::sample_manifest().unwrap();
        let mut spec = MockSpec::default();
        spec.fail_compile.insert("k.a.n8".to_string());
        let mut cache = CompileCache::new(Box::new(MockEngine::new(spec)));
        let v = manifest.variant("k.a.n8").unwrap().clone();
        assert!(cache.get_or_compile(&manifest, &v).is_err());
        assert_eq!(cache.stats().failures, 1);
        assert_eq!(cache.resident(), 0);
    }

    #[test]
    fn missing_artifact_file_is_io_error() {
        let text = crate::manifest::tests::sample_manifest_json();
        let m = Manifest::from_json_str(&text, PathBuf::from("/nonexistent-dir-xyz")).unwrap();
        // load_hlo reads the artifact from disk before the engine is ever
        // consulted; the missing directory must surface as an IO error.
        let mut cache = CompileCache::new(Box::new(MockEngine::new(MockSpec::default())));
        let v = m.variant("k.a.n8").unwrap().clone();
        let err = match cache.get_or_compile(&m, &v) {
            Err(e) => e,
            Ok(_) => panic!("expected IO error"),
        };
        assert!(err.to_string().contains("nonexistent-dir-xyz"));
    }
}
