//! Deterministic mock engine for tests, ablations and failure injection.
//!
//! The mock "compiles" and "executes" by spinning for configurable
//! durations, so the autotuner and coordinator observe realistic timing
//! behaviour with controlled ground truth: tests know which variant *is*
//! fastest and can assert the tuner finds it. Executions return a tensor
//! filled with the variant's tuning value, so routing is observable from
//! the output alone.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::manifest::Variant;
use crate::runtime::engine::{CompiledKernel, Engine, EngineFactory, SharedKernel};
use crate::sync::TrackedMutex;
use crate::tensor::HostTensor;
use crate::util::prng::Rng;

/// Shared run-time fault-injection handle: scale any variant's execution
/// cost — or make its next execution panic — *while the engine is
/// running*. Clone the handle out of a [`MockSpec`] before moving the
/// spec into an engine/coordinator, then flip scales mid-run — drift
/// tests and benches use this to degrade a published winner without
/// restarting anything, and the pool fault tests use [`panic_once`]
/// (one-shot) to kill a worker mid-job deterministically.
///
/// [`panic_once`]: LatencyFault::panic_once
///
/// Hot-path cost: with no shifts installed (the default), every
/// execution pays one relaxed atomic load — the shared mutex is touched
/// only once a fault has actually been injected, so the lock-free
/// fast-lane scaling the throughput bench measures stays lock-free.
#[derive(Debug, Clone, Default)]
pub struct LatencyFault {
    inner: Arc<FaultInner>,
}

#[derive(Debug)]
struct FaultInner {
    /// Fast-path gate: false until the first injection. Release store /
    /// Acquire load so an armed reader also sees the injected entries.
    armed: AtomicBool,
    scales: TrackedMutex<HashMap<String, f64>>,
    /// Variant ids whose *next* execution panics (one-shot: consumed by
    /// the execution that fires it).
    panics: TrackedMutex<HashSet<String>>,
    /// Variant ids whose every execution errors until cleared — unlike
    /// [`MockSpec::fail_execute`] (baked at compile time) this reaches
    /// kernels that are *already compiled and published*, which is what
    /// the erroring-winner chaos scenario needs.
    errors: TrackedMutex<HashSet<String>>,
}

impl Default for FaultInner {
    fn default() -> Self {
        FaultInner {
            armed: AtomicBool::new(false),
            scales: TrackedMutex::new("runtime.mock.fault.scales", HashMap::new()),
            panics: TrackedMutex::new("runtime.mock.fault.panics", HashSet::new()),
            errors: TrackedMutex::new("runtime.mock.fault.errors", HashSet::new()),
        }
    }
}

impl LatencyFault {
    /// A handle with no shifts installed (every variant at scale 1.0).
    pub fn new() -> LatencyFault {
        LatencyFault::default()
    }

    /// Multiply `variant_id`'s execution cost by `scale` from now on
    /// (1.0 restores health).
    pub fn set_scale(&self, variant_id: &str, scale: f64) {
        self.inner.scales.lock().insert(variant_id.to_string(), scale);
        self.inner.armed.store(true, Ordering::Release);
    }

    /// Make the *next* execution of `variant_id` panic — once. The
    /// injection clears itself when it fires, so the recovery path
    /// (fallback + worker respawn) can be observed deterministically
    /// without the retried call panicking again.
    pub fn panic_once(&self, variant_id: &str) {
        self.inner.panics.lock().insert(variant_id.to_string());
        self.inner.armed.store(true, Ordering::Release);
    }

    /// From now on, every execution of `variant_id` returns an error
    /// (until [`clear_error`](LatencyFault::clear_error) or
    /// [`clear`](LatencyFault::clear)). Reaches kernels that are already
    /// compiled and published — the erroring-winner chaos injection.
    pub fn fail_execute(&self, variant_id: &str) {
        self.inner.errors.lock().insert(variant_id.to_string());
        self.inner.armed.store(true, Ordering::Release);
    }

    /// Stop injecting execution errors for `variant_id`.
    pub fn clear_error(&self, variant_id: &str) {
        self.inner.errors.lock().remove(variant_id);
    }

    /// Remove every injected shift, pending panic and execution error.
    pub fn clear(&self) {
        let mut scales = self.inner.scales.lock();
        scales.clear();
        self.inner.panics.lock().clear();
        self.inner.errors.lock().clear();
        self.inner.armed.store(false, Ordering::Release);
    }

    fn scale_for(&self, variant_id: &str) -> f64 {
        if !self.inner.armed.load(Ordering::Acquire) {
            return 1.0;
        }
        self.inner.scales.lock().get(variant_id).copied().unwrap_or(1.0)
    }

    /// Consume a pending panic injection for `variant_id`, if any.
    fn take_panic(&self, variant_id: &str) -> bool {
        if !self.inner.armed.load(Ordering::Acquire) {
            return false;
        }
        self.inner.panics.lock().remove(variant_id)
    }

    fn should_error(&self, variant_id: &str) -> bool {
        if !self.inner.armed.load(Ordering::Acquire) {
            return false;
        }
        self.inner.errors.lock().contains(variant_id)
    }
}

/// Shared compile-failure injection keyed by *executing thread name*.
///
/// The pool names its workers deterministically (`jitune-pool-{idx}`),
/// so a rule `("k.b.n8", "jitune-pool-1")` makes exactly worker 1's
/// replication of that winner fail while workers 0 and 2 succeed — the
/// fixture for partial-install routing tests, which a process-wide
/// [`MockSpec::fail_compile`] set cannot express (every engine cloned
/// from a factory shares the spec, so it fails everywhere or nowhere).
///
/// Hot-path cost mirrors [`LatencyFault`]: one relaxed atomic load per
/// compile until the first rule is installed.
#[derive(Debug, Clone, Default)]
pub struct CompileFault {
    inner: Arc<CompileFaultInner>,
}

#[derive(Debug)]
struct CompileFaultInner {
    /// Fast-path gate: false until the first injection. Release store /
    /// Acquire load so an armed reader also sees the injected rules.
    armed: AtomicBool,
    /// `(variant id, exact thread name)` pairs whose compile fails.
    rules: TrackedMutex<Vec<(String, String)>>,
}

impl Default for CompileFaultInner {
    fn default() -> Self {
        CompileFaultInner {
            armed: AtomicBool::new(false),
            rules: TrackedMutex::new("runtime.mock.fault.compile_rules", Vec::new()),
        }
    }
}

impl CompileFault {
    /// A handle with no rules installed.
    pub fn new() -> CompileFault {
        CompileFault::default()
    }

    /// From now on, compiling `variant_id` fails on the thread named
    /// `thread_name` (and only there).
    pub fn fail_on_thread(&self, variant_id: &str, thread_name: &str) {
        self.inner.rules.lock().push((variant_id.to_string(), thread_name.to_string()));
        self.inner.armed.store(true, Ordering::Release);
    }

    /// Remove every rule.
    pub fn clear(&self) {
        self.inner.rules.lock().clear();
        self.inner.armed.store(false, Ordering::Release);
    }

    fn should_fail(&self, variant_id: &str) -> bool {
        if !self.inner.armed.load(Ordering::Acquire) {
            return false;
        }
        let current = std::thread::current();
        let name = current.name().unwrap_or("");
        self.inner.rules.lock().iter().any(|(v, t)| v == variant_id && t == name)
    }
}

/// Configuration for the mock engine.
#[derive(Debug, Clone)]
pub struct MockSpec {
    /// Cost of every JIT compilation (the paper's *C*).
    pub compile_cost: Duration,
    /// Per-variant execution cost; falls back to `default_exec_cost`.
    pub exec_cost: HashMap<String, Duration>,
    /// Execution cost for variants not listed in `exec_cost`.
    pub default_exec_cost: Duration,
    /// Multiplicative gaussian jitter (fraction of the base cost).
    pub jitter_frac: f64,
    /// Variant ids whose compilation fails (failure injection).
    pub fail_compile: HashSet<String>,
    /// Variant ids whose execution fails (failure injection).
    pub fail_execute: HashSet<String>,
    /// Jitter RNG seed.
    pub seed: u64,
    /// Model execution with `thread::sleep` instead of a busy spin.
    /// Sleeping frees the host CPU — the behaviour of a kernel offloaded
    /// to an accelerator — which is what the throughput-scaling bench
    /// needs to show lane scaling independent of host core count.
    pub exec_sleep: bool,
    /// Run-time latency-shift injection: clone this handle before moving
    /// the spec, then `set_scale` to degrade a variant mid-run.
    pub latency_fault: LatencyFault,
    /// Thread-targeted compile-failure injection (partial pool installs).
    pub compile_fault: CompileFault,
}

impl Default for MockSpec {
    fn default() -> Self {
        MockSpec {
            compile_cost: Duration::from_micros(200),
            exec_cost: HashMap::new(),
            default_exec_cost: Duration::from_micros(50),
            jitter_frac: 0.0,
            fail_compile: HashSet::new(),
            fail_execute: HashSet::new(),
            seed: 0x6a69_7475,
            exec_sleep: false,
            latency_fault: LatencyFault::new(),
            compile_fault: CompileFault::new(),
        }
    }
}

impl MockSpec {
    /// Builder helper: set a per-variant execution cost.
    pub fn with_cost(mut self, variant_id: &str, cost: Duration) -> Self {
        self.exec_cost.insert(variant_id.to_string(), cost);
        self
    }

    /// Builder helper: set the compile cost.
    pub fn with_compile_cost(mut self, cost: Duration) -> Self {
        self.compile_cost = cost;
        self
    }

    /// Builder helper: model execution with `thread::sleep` (accelerator
    /// offload) instead of a host-CPU busy spin.
    pub fn with_sleep_exec(mut self) -> Self {
        self.exec_sleep = true;
        self
    }
}

/// The mock engine.
pub struct MockEngine {
    spec: MockSpec,
    rng: TrackedMutex<Rng>,
    compiles: TrackedMutex<Vec<String>>,
}

impl MockEngine {
    /// Build from a spec.
    pub fn new(spec: MockSpec) -> MockEngine {
        let rng = TrackedMutex::new("runtime.mock.rng", Rng::seed(spec.seed));
        MockEngine { spec, rng, compiles: TrackedMutex::new("runtime.mock.compiles", Vec::new()) }
    }

    /// Variant ids compiled so far, in order (test observability).
    pub fn compiled_order(&self) -> Vec<String> {
        self.compiles.lock().clone()
    }
}

/// Spin-wait for `d` — `thread::sleep` is too coarse below ~1ms and the
/// mock needs microsecond-scale distinguishable costs.
pub fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl Engine for MockEngine {
    fn compile(&self, variant: &Variant, _hlo_text: &str) -> Result<Box<dyn CompiledKernel>> {
        if self.spec.fail_compile.contains(&variant.id) {
            return Err(Error::CompileFailed {
                variant: variant.id.clone(),
                msg: "injected compile failure".into(),
            });
        }
        if self.spec.compile_fault.should_fail(&variant.id) {
            return Err(Error::CompileFailed {
                variant: variant.id.clone(),
                msg: format!(
                    "injected compile failure on thread {:?}",
                    std::thread::current().name().unwrap_or("?")
                ),
            });
        }
        spin_for(self.spec.compile_cost);
        self.compiles.lock().push(variant.id.clone());
        let base = self
            .spec
            .exec_cost
            .get(&variant.id)
            .copied()
            .unwrap_or(self.spec.default_exec_cost);
        Ok(Box::new(MockKernel {
            inner: Arc::new(MockKernelState {
                variant_id: variant.id.clone(),
                value: variant.value,
                output_shape: variant.output_shape()?,
                base,
                jitter_frac: self.spec.jitter_frac,
                fail: self.spec.fail_execute.contains(&variant.id),
                sleep: self.spec.exec_sleep,
                fault: self.spec.latency_fault.clone(),
                rng: TrackedMutex::new("runtime.mock.kernel.rng", self.rng.lock().split()),
            }),
        }))
    }

    fn name(&self) -> &str {
        "mock"
    }
}

/// The sharable executable state: everything is `Send + Sync` (the RNG
/// sits behind a mutex), so the coordinator's fast lane can publish mock
/// kernels and run them from any application thread.
struct MockKernelState {
    variant_id: String,
    value: i64,
    output_shape: Vec<usize>,
    base: Duration,
    jitter_frac: f64,
    fail: bool,
    sleep: bool,
    fault: LatencyFault,
    rng: TrackedMutex<Rng>,
}

impl SharedKernel for MockKernelState {
    fn execute(&self, _inputs: &[HostTensor]) -> Result<HostTensor> {
        if self.fault.take_panic(&self.variant_id) {
            panic!("injected panic for {}", self.variant_id);
        }
        if self.fail || self.fault.should_error(&self.variant_id) {
            return Err(Error::Xla(format!("injected execute failure for {}", self.variant_id)));
        }
        let mut cost = self.base.as_secs_f64() * self.fault.scale_for(&self.variant_id);
        if self.jitter_frac > 0.0 {
            let z = self.rng.lock().normal();
            cost *= (1.0 + self.jitter_frac * z).max(0.1);
        }
        if self.sleep {
            std::thread::sleep(Duration::from_secs_f64(cost));
        } else {
            spin_for(Duration::from_secs_f64(cost));
        }
        // Output encodes the executed variant's tuning value — tests can
        // observe routing decisions from data alone.
        Ok(HostTensor::full(&self.output_shape, self.value as f32))
    }

    fn variant_id(&self) -> &str {
        &self.variant_id
    }
}

struct MockKernel {
    inner: Arc<MockKernelState>,
}

impl CompiledKernel for MockKernel {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        SharedKernel::execute(&*self.inner, inputs)
    }

    fn variant_id(&self) -> &str {
        &self.inner.variant_id
    }

    fn shared(&self) -> Option<Arc<dyn SharedKernel>> {
        Some(self.inner.clone())
    }
}

/// Wrapper that hides an engine's shareable handles: compiled kernels
/// delegate execution but always report `shared() -> None`, modelling a
/// thread-pinned backend (the PJRT shape) on top of any engine. Pool
/// tests and benches use it to force the coordinator off the shared
/// fast lane and onto the worker-pool path.
pub struct PinnedEngine {
    inner: Box<dyn Engine>,
    name: String,
}

impl PinnedEngine {
    /// Wrap `inner`, suppressing its kernels' shared handles.
    pub fn new(inner: Box<dyn Engine>) -> PinnedEngine {
        let name = format!("pinned({})", inner.name());
        PinnedEngine { inner, name }
    }
}

impl Engine for PinnedEngine {
    fn compile(&self, variant: &Variant, hlo_text: &str) -> Result<Box<dyn CompiledKernel>> {
        Ok(Box::new(PinnedKernel { inner: self.inner.compile(variant, hlo_text)? }))
    }

    fn name(&self) -> &str {
        &self.name
    }
}

struct PinnedKernel {
    inner: Box<dyn CompiledKernel>,
}

impl CompiledKernel for PinnedKernel {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        self.inner.execute(inputs)
    }

    fn variant_id(&self) -> &str {
        self.inner.variant_id()
    }

    // shared() keeps the default `None`: that is the whole point.
}

/// [`EngineFactory`] for mock engines: every `create` builds a fresh
/// [`MockEngine`] from a clone of the same spec, so all instances share
/// one [`LatencyFault`] handle (run-time injection reaches every pool
/// worker) while keeping independent RNGs and compile logs.
pub struct MockEngineFactory {
    spec: MockSpec,
    pinned: bool,
}

impl MockEngineFactory {
    /// Factory for plain mock engines (kernels are shareable).
    pub fn new(spec: MockSpec) -> MockEngineFactory {
        MockEngineFactory { spec, pinned: false }
    }

    /// Factory whose engines refuse `shared()` (wrapped in
    /// [`PinnedEngine`]): tuned calls cannot take the shared fast lane
    /// and must flow through the worker pool or the leader.
    pub fn pinned(spec: MockSpec) -> MockEngineFactory {
        MockEngineFactory { spec, pinned: true }
    }
}

impl EngineFactory for MockEngineFactory {
    fn create(&self) -> Result<Box<dyn Engine>> {
        let engine = MockEngine::new(self.spec.clone());
        Ok(if self.pinned {
            Box::new(PinnedEngine::new(Box::new(engine)))
        } else {
            Box::new(engine)
        })
    }

    fn name(&self) -> &str {
        if self.pinned {
            "mock-pinned"
        } else {
            "mock"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    fn manifest() -> Manifest {
        crate::manifest::tests::sample_manifest().unwrap()
    }

    #[test]
    fn output_encodes_variant_value() {
        let m = manifest();
        let engine = MockEngine::new(MockSpec::default());
        let v = m.variant("k.b.n8").unwrap();
        let kernel = engine.compile(v, "").unwrap();
        let out = kernel.execute(&[]).unwrap();
        assert_eq!(out.shape(), &[8, 8]);
        assert!(out.data().iter().all(|&x| x == 2.0)); // value of k.b.n8
    }

    #[test]
    fn exec_cost_is_respected() {
        let m = manifest();
        let spec = MockSpec::default()
            .with_cost("k.a.n8", Duration::from_micros(800))
            .with_cost("k.b.n8", Duration::from_micros(50));
        let engine = MockEngine::new(spec);
        let slow = engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        let fast = engine.compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        let t0 = Instant::now();
        slow.execute(&[]).unwrap();
        let slow_t = t0.elapsed();
        let t1 = Instant::now();
        fast.execute(&[]).unwrap();
        let fast_t = t1.elapsed();
        assert!(slow_t > fast_t * 2, "slow={slow_t:?} fast={fast_t:?}");
    }

    #[test]
    fn injected_failures() {
        let m = manifest();
        let mut spec = MockSpec::default();
        spec.fail_compile.insert("k.a.n8".into());
        spec.fail_execute.insert("k.b.n8".into());
        let engine = MockEngine::new(spec);
        assert!(engine.compile(m.variant("k.a.n8").unwrap(), "").is_err());
        let kernel = engine.compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        assert!(kernel.execute(&[]).is_err());
    }

    #[test]
    fn compiled_order_recorded() {
        let m = manifest();
        let engine = MockEngine::new(MockSpec::default());
        engine.compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        assert_eq!(engine.compiled_order(), vec!["k.b.n8".to_string(), "k.a.n8".to_string()]);
    }

    #[test]
    fn kernels_are_shareable_across_threads() {
        let m = manifest();
        let engine = MockEngine::new(MockSpec::default());
        let kernel = engine.compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        let shared = kernel.shared().expect("mock kernels are shareable");
        assert_eq!(shared.variant_id(), "k.b.n8");
        let join = std::thread::spawn(move || shared.execute(&[]).unwrap());
        let out = join.join().unwrap();
        assert!(out.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn latency_fault_scales_execution_mid_run() {
        let m = manifest();
        let spec = MockSpec::default().with_cost("k.a.n8", Duration::from_micros(100));
        let fault = spec.latency_fault.clone();
        let engine = MockEngine::new(spec);
        let kernel = engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();

        let time_one = |k: &dyn CompiledKernel| {
            let t0 = Instant::now();
            k.execute(&[]).unwrap();
            t0.elapsed()
        };
        let healthy = time_one(kernel.as_ref());
        // degrade 10x without recompiling — the already-compiled kernel
        // sees the shift on its next execution
        fault.set_scale("k.a.n8", 10.0);
        let degraded = time_one(kernel.as_ref());
        assert!(
            degraded > healthy * 4,
            "healthy={healthy:?} degraded={degraded:?}"
        );
        fault.clear();
        let recovered = time_one(kernel.as_ref());
        assert!(recovered < degraded / 2, "clear() restores health: {recovered:?}");
    }

    #[test]
    fn pinned_factory_suppresses_shared_handles() {
        let m = manifest();
        let factory = MockEngineFactory::pinned(MockSpec::default());
        assert_eq!(factory.name(), "mock-pinned");
        let engine = factory.create().unwrap();
        assert!(engine.name().starts_with("pinned("), "{}", engine.name());
        let kernel = engine.compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        assert!(kernel.shared().is_none(), "pinned kernels must refuse shared()");
        // execution still delegates to the wrapped mock
        let out = kernel.execute(&[]).unwrap();
        assert!(out.data().iter().all(|&x| x == 2.0));

        let plain = MockEngineFactory::new(MockSpec::default());
        let kernel = plain.create().unwrap().compile(m.variant("k.b.n8").unwrap(), "").unwrap();
        assert!(kernel.shared().is_some(), "plain factory keeps shareability");
    }

    #[test]
    fn factory_instances_share_the_fault_handle() {
        let m = manifest();
        let spec = MockSpec::default().with_cost("k.a.n8", Duration::from_micros(100));
        let fault = spec.latency_fault.clone();
        let factory = MockEngineFactory::new(spec);
        let a = factory.create().unwrap();
        let b = factory.create().unwrap();
        let ka = a.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        let kb = b.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        fault.set_scale("k.a.n8", 10.0);
        // both engine instances observe the injection
        for k in [ka.as_ref(), kb.as_ref()] {
            let t0 = Instant::now();
            k.execute(&[]).unwrap();
            assert!(t0.elapsed() > Duration::from_micros(500), "fault reaches {}", k.variant_id());
        }
    }

    #[test]
    fn panic_once_fires_exactly_once() {
        let m = manifest();
        let spec = MockSpec::default();
        let fault = spec.latency_fault.clone();
        let engine = MockEngine::new(spec);
        let kernel = engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        kernel.execute(&[]).unwrap();
        fault.panic_once("k.a.n8");
        let shared = kernel.shared().unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = shared.execute(&[]);
        }));
        assert!(caught.is_err(), "injected panic fires");
        // one-shot: the next execution is healthy again
        kernel.execute(&[]).unwrap();
    }

    #[test]
    fn fail_execute_reaches_published_kernels_and_clears() {
        let m = manifest();
        let spec = MockSpec::default();
        let fault = spec.latency_fault.clone();
        let engine = MockEngine::new(spec);
        // compiled *before* the injection — the run-time toggle must
        // still reach it, unlike MockSpec::fail_execute
        let kernel = engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        kernel.execute(&[]).unwrap();
        fault.fail_execute("k.a.n8");
        assert!(kernel.execute(&[]).is_err(), "injected error fires");
        assert!(kernel.execute(&[]).is_err(), "and keeps firing until cleared");
        fault.clear_error("k.a.n8");
        kernel.execute(&[]).unwrap();
    }

    #[test]
    fn jitter_produces_spread_but_stays_positive() {
        let m = manifest();
        let spec = MockSpec {
            jitter_frac: 0.3,
            default_exec_cost: Duration::from_micros(100),
            ..MockSpec::default()
        };
        let engine = MockEngine::new(spec);
        let kernel = engine.compile(m.variant("k.a.n8").unwrap(), "").unwrap();
        let mut times = Vec::new();
        for _ in 0..10 {
            let t0 = Instant::now();
            kernel.execute(&[]).unwrap();
            times.push(t0.elapsed().as_secs_f64());
        }
        assert!(times.iter().all(|&t| t > 0.0));
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        assert!(max > min, "jitter should spread timings");
    }
}
