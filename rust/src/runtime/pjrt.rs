//! Real PJRT engine: HLO text → `client.compile` → execute.
//!
//! Follows the working pattern from /opt/xla-example/load_hlo: artifacts
//! are HLO **text** (jax ≥ 0.5 protos are rejected by xla_extension 0.5.1),
//! lowered with `return_tuple=True` so every output is a 1-tuple.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::manifest::Variant;
use crate::runtime::engine::{CompiledKernel, Engine, EngineFactory};
use crate::tensor::HostTensor;

/// [`EngineFactory`] for per-worker PJRT engines: each pool worker calls
/// `create` on its own thread and gets a private client there (PJRT
/// clients are thread-pinned), which is exactly what extends tuned-lane
/// scaling to the real backend — one client per worker, replicated
/// finalization, no executable ever crossing a thread.
pub struct PjrtEngineFactory;

impl EngineFactory for PjrtEngineFactory {
    fn create(&self) -> Result<Box<dyn Engine>> {
        Ok(Box::new(PjrtEngine::cpu()?))
    }

    fn name(&self) -> &str {
        "pjrt-cpu"
    }
}

/// PJRT CPU backend.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

impl PjrtEngine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "pjrt engine: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtEngine { client })
    }

    /// Platform reported by the PJRT plugin ("cpu" here).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Engine for PjrtEngine {
    fn compile(&self, variant: &Variant, hlo_text: &str) -> Result<Box<dyn CompiledKernel>> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(hlo_text.as_bytes())
            .map_err(|e| Error::CompileFailed {
            variant: variant.id.clone(),
            msg: format!("hlo parse: {e}"),
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| Error::CompileFailed {
            variant: variant.id.clone(),
            msg: e.to_string(),
        })?;
        log::debug!("compiled {} in {:.1}ms", variant.id, t0.elapsed().as_secs_f64() * 1e3);
        Ok(Box::new(PjrtKernel {
            exe,
            variant_id: variant.id.clone(),
            input_shapes: variant.input_shapes()?,
            output_shape: variant.output_shape()?,
        }))
    }

    fn name(&self) -> &str {
        "pjrt-cpu"
    }
}

struct PjrtKernel {
    exe: xla::PjRtLoadedExecutable,
    variant_id: String,
    input_shapes: Vec<Vec<usize>>,
    output_shape: Vec<usize>,
}

impl PjrtKernel {
    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.input_shapes.len() {
            return Err(Error::ShapeMismatch {
                kernel: self.variant_id.clone(),
                expected: format!("{} inputs", self.input_shapes.len()),
                got: format!("{} inputs", inputs.len()),
            });
        }
        for (i, (t, want)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if t.shape() != want.as_slice() {
                return Err(Error::ShapeMismatch {
                    kernel: format!("{} (input {i})", self.variant_id),
                    expected: format!("{want:?}"),
                    got: format!("{:?}", t.shape()),
                });
            }
        }
        Ok(())
    }
}

impl CompiledKernel for PjrtKernel {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        self.check_inputs(inputs)?;
        // §Perf: single-copy literal construction. The original
        // `vec1(..).reshape(..)` path allocated a rank-1 literal and then
        // a second, reshaped one per input per call.
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        t.data().as_ptr() as *const u8,
                        t.data().len() * std::mem::size_of::<f32>(),
                    )
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    t.shape(),
                    bytes,
                )
                .map_err(Error::from)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        HostTensor::from_vec(&self.output_shape, data)
    }

    fn variant_id(&self) -> &str {
        &self.variant_id
    }
}
