//! Per-engine scratch-buffer pool: reusable cache-line-aligned `f32`
//! slabs keyed by power-of-two size class, modeled on kubecl's exclusive
//! memory pool (one handle owns one slab; the slab returns to its class's
//! free list when the handle drops).
//!
//! Native kernels allocate real scratch — the transpose-schedule matmul
//! packs an `n*n` panel per call — and without a pool every pool-worker
//! execution pays a fresh multi-megabyte allocation + page-fault storm.
//! With the pool, the first call per size class allocates and every
//! subsequent call recycles ([`PoolStats`] makes the hit rate
//! observable, and `tests/native_engine.rs` asserts it).
//!
//! Alignment: slabs are over-allocated by one cache line and handed out
//! at a 64-byte-aligned offset, so tile loops never straddle an extra
//! line and the alignment is real rather than "whatever the allocator
//! gave us" — done with safe pointer arithmetic on `as_ptr()`, no
//! `unsafe`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::sync::TrackedMutex;

/// Floats per 64-byte cache line.
const LINE_F32: usize = 16;

/// Max recycled slabs retained per size class; beyond this, returned
/// slabs are dropped (kubecl's "max allocations" bound — keeps a burst
/// of concurrent takes from pinning memory forever).
const MAX_PER_CLASS: usize = 8;

/// Counters for pool observability. Loads/stores are relaxed: the
/// counters are monotonic telemetry, never used for synchronization.
#[derive(Debug, Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    returned: AtomicU64,
    bytes_live: AtomicU64,
}

/// Snapshot of pool activity (see [`BufferPool::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Takes served from a recycled slab.
    pub hits: u64,
    /// Takes that had to allocate.
    pub misses: u64,
    /// Slabs returned to a free list (drops past the per-class cap are
    /// not counted).
    pub returned: u64,
    /// Bytes currently allocated by the pool (live handles + free
    /// lists).
    pub bytes_live: u64,
}

#[derive(Debug)]
struct PoolShared {
    /// size class (slab length in f32s, power of two) -> free slabs.
    classes: TrackedMutex<HashMap<usize, Vec<Vec<f32>>>>,
    counters: Counters,
}

/// The pool. Cheap to clone (`Arc` inside); every engine owns one and
/// threads it into each kernel it compiles.
#[derive(Debug, Clone)]
pub struct BufferPool {
    shared: Arc<PoolShared>,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        BufferPool {
            shared: Arc::new(PoolShared {
                classes: TrackedMutex::new("runtime.native.pool.classes", HashMap::new()),
                counters: Counters::default(),
            }),
        }
    }

    /// Take a zero-initialized-on-first-use scratch buffer of at least
    /// `len` f32s, 64-byte aligned. Recycled slabs keep their previous
    /// contents — callers must treat the buffer as uninitialized and
    /// write before reading.
    pub fn take(&self, len: usize) -> PoolBuffer {
        let class = len.next_power_of_two().max(LINE_F32);
        let recycled = self.shared.classes.lock().get_mut(&class).and_then(Vec::pop);
        let raw = match recycled {
            Some(raw) => {
                // relaxed-counter: telemetry only, no ordering required
                self.shared.counters.hits.fetch_add(1, Ordering::Relaxed);
                raw
            }
            None => {
                // relaxed-counter: telemetry only, no ordering required
                self.shared.counters.misses.fetch_add(1, Ordering::Relaxed);
                let slab = vec![0.0f32; class + LINE_F32];
                // relaxed-counter: telemetry only, no ordering required
                self.shared
                    .counters
                    .bytes_live
                    .fetch_add((slab.len() * 4) as u64, Ordering::Relaxed);
                slab
            }
        };
        // Offset the view so it starts on a 64-byte boundary. The slab
        // is over-allocated by a full line, so offset + len always fits.
        let addr = raw.as_ptr() as usize;
        let offset = (((addr + 63) & !63) - addr) / 4;
        PoolBuffer { raw, offset, len, pool: self.shared.clone() }
    }

    /// Activity counters.
    pub fn stats(&self) -> PoolStats {
        let c = &self.shared.counters;
        PoolStats {
            // relaxed-counter: telemetry only, no ordering required
            hits: c.hits.load(Ordering::Relaxed),
            // relaxed-counter: telemetry only, no ordering required
            misses: c.misses.load(Ordering::Relaxed),
            // relaxed-counter: telemetry only, no ordering required
            returned: c.returned.load(Ordering::Relaxed),
            // relaxed-counter: telemetry only, no ordering required
            bytes_live: c.bytes_live.load(Ordering::Relaxed),
        }
    }
}

/// Exclusive handle to a pooled slab. Derefs to the aligned `[f32]`
/// window; returns the slab to its size class on drop.
#[derive(Debug)]
pub struct PoolBuffer {
    raw: Vec<f32>,
    offset: usize,
    len: usize,
    pool: Arc<PoolShared>,
}

impl PoolBuffer {
    /// The aligned scratch window.
    pub fn as_slice(&self) -> &[f32] {
        &self.raw[self.offset..self.offset + self.len]
    }

    /// The aligned scratch window, mutably.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.raw[self.offset..self.offset + self.len]
    }
}

impl Drop for PoolBuffer {
    fn drop(&mut self) {
        let raw = std::mem::take(&mut self.raw);
        let class = raw.len() - LINE_F32;
        let mut classes = self.pool.classes.lock();
        let list = classes.entry(class).or_default();
        if list.len() < MAX_PER_CLASS {
            list.push(raw);
            // relaxed-counter: telemetry only, no ordering required
            self.pool.counters.returned.fetch_add(1, Ordering::Relaxed);
        } else {
            // relaxed-counter: telemetry only, no ordering required
            self.pool.counters.bytes_live.fetch_sub((raw.len() * 4) as u64, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_aligned_and_sized() {
        let pool = BufferPool::new();
        for len in [1usize, 7, 16, 100, 4096, 1 << 20] {
            let buf = pool.take(len);
            assert_eq!(buf.as_slice().len(), len);
            assert_eq!(buf.as_slice().as_ptr() as usize % 64, 0, "len={len}");
        }
    }

    #[test]
    fn second_take_recycles() {
        let pool = BufferPool::new();
        {
            let mut a = pool.take(1000);
            a.as_mut_slice()[0] = 7.0;
        }
        let b = pool.take(900); // same class (1024)
        let s = pool.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.returned, 1);
        drop(b);
    }

    #[test]
    fn per_class_cap_bounds_memory() {
        let pool = BufferPool::new();
        let held: Vec<PoolBuffer> = (0..MAX_PER_CLASS + 4).map(|_| pool.take(256)).collect();
        let live_before = pool.stats().bytes_live;
        drop(held);
        let s = pool.stats();
        assert_eq!(s.returned as usize, MAX_PER_CLASS);
        assert!(s.bytes_live < live_before, "drops past the cap release memory");
    }

    #[test]
    fn distinct_classes_do_not_share() {
        let pool = BufferPool::new();
        drop(pool.take(256));
        let _big = pool.take(4096);
        assert_eq!(pool.stats().hits, 0, "4096 must not reuse the 256-class slab");
    }
}
