//! Real CPU kernels with tunable schedules.
//!
//! Every kernel family exposes several *variants* that compute the exact
//! same function but walk memory / issue arithmetic differently, so the
//! manifest's tuning parameter genuinely changes machine behaviour:
//!
//! - **matmul** (`sched`): naive ijk (strided column walks of B), a
//!   transpose-into-scratch schedule (packs `Bᵀ` into a pooled panel so
//!   both operands stream), and tiled ikj schedules at several tile
//!   sizes with optional 4-way inner-loop unrolling.
//! - **saxpy** (`access`): strided multi-pass walks (cache-hostile on
//!   large vectors) vs. chunked/sequential single-pass.
//! - **reduce** (`lanes`): sequential single-accumulator sum vs. a
//!   lane-split tree reduction (N independent accumulators combined
//!   pairwise) that breaks the add-latency dependency chain.
//!
//! ## Bit-identity contract
//!
//! The tuner must never be able to pick a *wrong-but-fast* winner, so
//! all variants of a family are constructed to produce **bit-identical
//! `f32` outputs**:
//!
//! - matmul: every variant accumulates each `C[i][j]` in `f32`, over
//!   `k` in ascending order, one product per step. Tiling over `i`/`k`
//!   and unrolling over `j` permute *which element* is updated next but
//!   never the per-element operand order, so the float operation
//!   sequence per output element is literally identical.
//! - saxpy: elementwise; each element is computed exactly once by one
//!   fused expression regardless of visit order.
//! - reduce: all variants accumulate in `f64` and round to `f32` once
//!   at the end. Lane-splitting permutes the `f64` summation order,
//!   whose error (~1e-16 relative per step) is ~1e7× below the final
//!   `f32` rounding step, so the rounded result is identical on real
//!   data (asserted on seeded inputs by `tests/native_engine.rs`).

use crate::error::{Error, Result};
use crate::manifest::Variant;

use super::mempool::BufferPool;

/// Matmul schedule, decoded from the variant's packed tuning value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulSched {
    /// ijk, k innermost: B is walked down columns (stride `4n` bytes) —
    /// the cache-hostile baseline.
    Naive,
    /// Transpose B into pooled scratch, then row·row dot products: both
    /// operands stream. Exercises [`BufferPool`] on the serve path.
    Transposed,
    /// ikj with `tile`×`tile` blocking over i/k and the inner j loop
    /// unrolled by `unroll` (1 or 4).
    Tiled {
        /// Block edge over the i and k loops.
        tile: usize,
        /// Unroll factor of the innermost j loop.
        unroll: usize,
    },
}

/// Saxpy access pattern, decoded from the variant's tuning value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaxpyAccess {
    /// `stride` passes over the vector, pass `p` touching elements
    /// `p, p+stride, …` — on vectors larger than cache every touch is a
    /// fresh line fetch.
    Strided(usize),
    /// Sequential passes over `chunk`-element windows (one pass when
    /// `chunk >= len`).
    Chunked(usize),
}

/// A fully-decoded native kernel configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelCfg {
    /// `C = A·B`, square `n×n` f32.
    Matmul {
        /// Matrix edge.
        n: usize,
        /// Schedule variant.
        sched: MatmulSched,
    },
    /// `out = a·x + y` over `len` f32s.
    Saxpy {
        /// Vector length.
        len: usize,
        /// Access-pattern variant.
        access: SaxpyAccess,
    },
    /// `out[0] = Σ x`, accumulated in f64.
    Reduce {
        /// Vector length.
        len: usize,
        /// Number of parallel accumulator lanes (1 = sequential).
        lanes: usize,
    },
}

/// Kernel-family names the native engine understands.
pub const FAMILIES: &[&str] = &["matmul", "saxpy", "reduce"];

impl KernelCfg {
    /// Decode a manifest variant into a native kernel configuration.
    ///
    /// Value packing (one `i64` per manifest schema v1):
    /// - matmul: `1` = naive, `2` = transposed, `tile*100 + unroll`
    ///   otherwise.
    /// - saxpy: `< 1000` = strided with that stride, `1000 + chunk` =
    ///   chunked.
    /// - reduce: the lane count.
    pub fn parse(variant: &Variant) -> Result<KernelCfg> {
        let size = variant.size;
        if size <= 0 {
            return Err(Error::Manifest(format!(
                "native variant {}: non-positive size {size}",
                variant.id
            )));
        }
        let v = variant.value;
        let bad = |msg: &str| {
            Err(Error::Manifest(format!(
                "native variant {}: bad tuning value {v}: {msg}",
                variant.id
            )))
        };
        match variant.kernel.as_str() {
            "matmul" => {
                let n = size as usize;
                let sched = match v {
                    1 => MatmulSched::Naive,
                    2 => MatmulSched::Transposed,
                    _ => {
                        let (tile, unroll) = ((v / 100) as usize, (v % 100) as usize);
                        if tile == 0 || !(unroll == 1 || unroll == 4) {
                            return bad("expect 1, 2, or tile*100+unroll with unroll in {1,4}");
                        }
                        MatmulSched::Tiled { tile, unroll }
                    }
                };
                Ok(KernelCfg::Matmul { n, sched })
            }
            "saxpy" => {
                let len = size as usize;
                let access = if v >= 1000 {
                    SaxpyAccess::Chunked((v - 1000) as usize)
                } else if v >= 1 {
                    SaxpyAccess::Strided(v as usize)
                } else {
                    return bad("expect stride (<1000) or 1000+chunk");
                };
                Ok(KernelCfg::Saxpy { len, access })
            }
            "reduce" => {
                if v < 1 || v > 1024 {
                    return bad("lane count out of range");
                }
                Ok(KernelCfg::Reduce { len: size as usize, lanes: v as usize })
            }
            other => Err(Error::Unknown { kind: "native kernel", name: other.to_string() }),
        }
    }

    /// Output length in f32s.
    pub fn output_len(&self) -> usize {
        match *self {
            KernelCfg::Matmul { n, .. } => n * n,
            KernelCfg::Saxpy { len, .. } => len,
            KernelCfg::Reduce { .. } => 1,
        }
    }

    /// Execute into `out` (already sized to [`Self::output_len`]).
    /// `inputs` are the raw data slices of the call's tensors, in
    /// manifest signature order.
    pub fn run(&self, inputs: &[&[f32]], out: &mut [f32], pool: &BufferPool) -> Result<()> {
        match *self {
            KernelCfg::Matmul { n, sched } => {
                let (a, b) = (want(inputs, 0, n * n)?, want(inputs, 1, n * n)?);
                matmul(sched, a, b, out, n, pool);
            }
            KernelCfg::Saxpy { len, access } => {
                let a = want(inputs, 0, 1)?[0];
                let (x, y) = (want(inputs, 1, len)?, want(inputs, 2, len)?);
                saxpy(access, a, x, y, out);
            }
            KernelCfg::Reduce { len, lanes } => {
                out[0] = reduce(lanes, want(inputs, 0, len)?);
            }
        }
        Ok(())
    }
}

/// Fetch input `idx` and check its length (belt-and-braces: the
/// dispatcher already validated the call signature).
fn want<'a>(inputs: &[&'a [f32]], idx: usize, len: usize) -> Result<&'a [f32]> {
    match inputs.get(idx) {
        Some(s) if s.len() == len => Ok(s),
        Some(s) => Err(Error::Xla(format!(
            "native kernel: input {idx} has {} elements, expected {len}",
            s.len()
        ))),
        None => Err(Error::Xla(format!("native kernel: missing input {idx}"))),
    }
}

fn matmul(sched: MatmulSched, a: &[f32], b: &[f32], out: &mut [f32], n: usize, pool: &BufferPool) {
    match sched {
        MatmulSched::Naive => {
            for i in 0..n {
                let arow = &a[i * n..(i + 1) * n];
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for (k, &av) in arow.iter().enumerate() {
                        acc += av * b[k * n + j];
                    }
                    out[i * n + j] = acc;
                }
            }
        }
        MatmulSched::Transposed => {
            let mut bt = pool.take(n * n);
            let bts = bt.as_mut_slice();
            for k in 0..n {
                let brow = &b[k * n..(k + 1) * n];
                for (j, &bv) in brow.iter().enumerate() {
                    bts[j * n + k] = bv;
                }
            }
            for i in 0..n {
                let arow = &a[i * n..(i + 1) * n];
                for j in 0..n {
                    let btrow = &bts[j * n..(j + 1) * n];
                    let mut acc = 0.0f32;
                    for k in 0..n {
                        acc += arow[k] * btrow[k];
                    }
                    out[i * n + j] = acc;
                }
            }
        }
        MatmulSched::Tiled { tile, unroll } => {
            // out is accumulated in place and must start at zero.
            out.fill(0.0);
            for i0 in (0..n).step_by(tile) {
                let imax = (i0 + tile).min(n);
                for k0 in (0..n).step_by(tile) {
                    let kmax = (k0 + tile).min(n);
                    for i in i0..imax {
                        let arow = &a[i * n..(i + 1) * n];
                        let orow = &mut out[i * n..(i + 1) * n];
                        for k in k0..kmax {
                            let av = arow[k];
                            let brow = &b[k * n..(k + 1) * n];
                            if unroll == 4 {
                                let mut j = 0;
                                while j + 4 <= n {
                                    orow[j] += av * brow[j];
                                    orow[j + 1] += av * brow[j + 1];
                                    orow[j + 2] += av * brow[j + 2];
                                    orow[j + 3] += av * brow[j + 3];
                                    j += 4;
                                }
                                while j < n {
                                    orow[j] += av * brow[j];
                                    j += 1;
                                }
                            } else {
                                for j in 0..n {
                                    orow[j] += av * brow[j];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

fn saxpy(access: SaxpyAccess, a: f32, x: &[f32], y: &[f32], out: &mut [f32]) {
    let len = x.len();
    match access {
        SaxpyAccess::Strided(stride) => {
            let stride = stride.max(1);
            for phase in 0..stride.min(len) {
                let mut i = phase;
                while i < len {
                    out[i] = a * x[i] + y[i];
                    i += stride;
                }
            }
        }
        SaxpyAccess::Chunked(chunk) => {
            let chunk = chunk.max(1);
            let mut c0 = 0;
            while c0 < len {
                let c1 = (c0 + chunk).min(len);
                for i in c0..c1 {
                    out[i] = a * x[i] + y[i];
                }
                c0 = c1;
            }
        }
    }
}

fn reduce(lanes: usize, x: &[f32]) -> f32 {
    if lanes <= 1 {
        let mut acc = 0.0f64;
        for &v in x {
            acc += v as f64;
        }
        return acc as f32;
    }
    let lanes = lanes.min(x.len().max(1));
    let mut acc = vec![0.0f64; lanes];
    let main = x.len() - x.len() % lanes;
    let mut i = 0;
    while i < main {
        for (j, slot) in acc.iter_mut().enumerate() {
            *slot += x[i + j] as f64;
        }
        i += lanes;
    }
    for &v in &x[main..] {
        acc[0] += v as f64;
    }
    // Pairwise tree combine of the lane partials.
    let mut width = lanes;
    while width > 1 {
        let half = (width + 1) / 2;
        for j in 0..width / 2 {
            acc[j] = acc[2 * j] + acc[2 * j + 1];
        }
        if width % 2 == 1 {
            acc[half - 1] = acc[width - 1];
        }
        width = half;
    }
    acc[0] as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::prng::Rng::seed(seed);
        (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
    }

    #[test]
    fn matmul_variants_bit_identical() {
        let n = 48; // not a multiple of 32/64: exercises tile remainders
        let (a, b) = (seeded(n * n, 1), seeded(n * n, 2));
        let pool = BufferPool::new();
        let mut base = vec![0.0f32; n * n];
        matmul(MatmulSched::Naive, &a, &b, &mut base, n, &pool);
        for sched in [
            MatmulSched::Transposed,
            MatmulSched::Tiled { tile: 8, unroll: 1 },
            MatmulSched::Tiled { tile: 32, unroll: 1 },
            MatmulSched::Tiled { tile: 32, unroll: 4 },
            MatmulSched::Tiled { tile: 64, unroll: 4 },
        ] {
            let mut out = vec![0.0f32; n * n];
            matmul(sched, &a, &b, &mut out, n, &pool);
            assert_eq!(base, out, "{sched:?} diverged from naive");
        }
    }

    #[test]
    fn saxpy_variants_bit_identical() {
        let len = 1000; // not a multiple of any stride/chunk
        let (x, y) = (seeded(len, 3), seeded(len, 4));
        let mut base = vec![0.0f32; len];
        saxpy(SaxpyAccess::Chunked(len), 2.5, &x, &y, &mut base);
        for access in [
            SaxpyAccess::Strided(8),
            SaxpyAccess::Strided(32),
            SaxpyAccess::Chunked(256),
            SaxpyAccess::Chunked(4096),
        ] {
            let mut out = vec![0.0f32; len];
            saxpy(access, 2.5, &x, &y, &mut out);
            assert_eq!(base, out, "{access:?} diverged");
        }
    }

    #[test]
    fn reduce_variants_identical_after_rounding() {
        let x = seeded(100_000, 5);
        let base = reduce(1, &x);
        for lanes in [2, 4, 8, 16, 32] {
            assert_eq!(base.to_bits(), reduce(lanes, &x).to_bits(), "lanes={lanes}");
        }
    }

    #[test]
    fn reduce_matches_plain_sum() {
        let x = seeded(10_000, 6);
        let expect: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((reduce(8, &x) as f64 - expect).abs() < 1e-3);
    }

    #[test]
    fn parse_rejects_garbage() {
        // Parsing is exercised end-to-end in tests/native_engine.rs; here
        // just the guard rails.
        assert!(matches!(
            KernelCfg::parse(&bad_variant("matmul", 77)),
            Err(Error::Manifest(_))
        ));
        assert!(matches!(
            KernelCfg::parse(&bad_variant("reduce", 0)),
            Err(Error::Manifest(_))
        ));
        assert!(matches!(
            KernelCfg::parse(&bad_variant("conv", 1)),
            Err(Error::Unknown { .. })
        ));
    }

    fn bad_variant(kernel: &str, value: i64) -> Variant {
        Variant {
            id: format!("{kernel}.test.n8"),
            kernel: kernel.to_string(),
            param: "p".into(),
            value,
            label: "test".into(),
            size: 8,
            inputs: vec!["f32[8,8]".into(), "f32[8,8]".into()],
            output: "f32[8,8]".into(),
            path: "none.hlo.txt".into(),
            flops: 1,
        }
    }
}
