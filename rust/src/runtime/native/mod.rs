//! Real CPU-native engine: kernels whose tuning parameters change actual
//! machine behaviour.
//!
//! Everything upstream of this module was validated against
//! [`crate::runtime::mock`], whose "kernels" spin for configured
//! durations — ground truth by fiat. `NativeEngine` replaces fiat with
//! hardware: its variants are real tiled/unrolled matmuls, strided vs.
//! chunked saxpy walks and sequential-vs-tree reductions
//! ([`kernels`]), so a winner found by the tuner reflects genuine cache
//! and ILP behaviour of the machine it runs on, and the spread between
//! worst and best variant (asserted ≥1.3x by `benches/traffic_replay`)
//! is a property of silicon, not of the spec.
//!
//! Pieces:
//!
//! - [`kernels`] — the compute, with a strict bit-identity contract
//!   across the variants of each family (a wrong-but-fast winner is
//!   impossible by construction; `tests/native_engine.rs` asserts it).
//! - [`mempool::BufferPool`] — per-engine recycled, 64-byte-aligned
//!   scratch slabs keyed by size class (kubecl's exclusive-pool shape),
//!   so pool workers stop paying per-call allocation for kernel
//!   scratch.
//! - [`NativeFault`] — run-time interference injection: make a kernel
//!   family do N extra *real* compute passes, so drift tests degrade a
//!   published winner with genuine work rather than synthetic sleeps.
//! - [`NativeEngineFactory`] — `new`/`pinned` construction mirroring
//!   [`MockEngineFactory`], so the native engine slots into the fast
//!   lane, the worker pool and background shadow exploration unchanged.
//! - [`native_manifest`] — a generated manifest over the native variant
//!   catalog (stub HLO artifacts on disk for the compile cache; the
//!   engine compiles from the variant's packed tuning value, not from
//!   HLO).
//!
//! [`MockEngineFactory`]: crate::runtime::mock::MockEngineFactory

pub mod kernels;
pub mod mempool;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::manifest::{Manifest, Variant};
use crate::runtime::engine::{CompiledKernel, Engine, EngineFactory, SharedKernel};
use crate::runtime::mock::PinnedEngine;
use crate::sync::TrackedMutex;
use crate::tensor::HostTensor;

pub use kernels::{KernelCfg, MatmulSched, SaxpyAccess};
pub use mempool::{BufferPool, PoolBuffer, PoolStats};

/// Shared run-time interference handle: make every execution of a
/// kernel family perform `1 + extra` full compute passes. Unlike the
/// mock's [`LatencyFault`] this injects *real work* — the extra passes
/// hit the same memory and ALUs — so drift detection and retuning are
/// exercised against genuine hardware slowdown.
///
/// Clone the handle out of a factory before building the coordinator,
/// then [`slow_down`] mid-run. Hot-path cost when disarmed: one relaxed
/// atomic load.
///
/// [`LatencyFault`]: crate::runtime::mock::LatencyFault
/// [`slow_down`]: NativeFault::slow_down
#[derive(Debug, Clone, Default)]
pub struct NativeFault {
    inner: Arc<NativeFaultInner>,
}

#[derive(Debug)]
struct NativeFaultInner {
    /// Fast-path gate: false until the first injection. Release store /
    /// Acquire load so an armed reader also sees the injected entries.
    armed: AtomicBool,
    extra: TrackedMutex<HashMap<String, u32>>,
}

impl Default for NativeFaultInner {
    fn default() -> Self {
        NativeFaultInner {
            armed: AtomicBool::new(false),
            extra: TrackedMutex::new("runtime.native.fault.extra", HashMap::new()),
        }
    }
}

impl NativeFault {
    /// A handle with no interference installed.
    pub fn new() -> NativeFault {
        NativeFault::default()
    }

    /// From now on, every execution of `kernel` performs `extra`
    /// additional full compute passes (0 restores health).
    pub fn slow_down(&self, kernel: &str, extra: u32) {
        self.inner.extra.lock().insert(kernel.to_string(), extra);
        self.inner.armed.store(true, Ordering::Release);
    }

    /// Remove all interference.
    pub fn clear(&self) {
        self.inner.extra.lock().clear();
        self.inner.armed.store(false, Ordering::Release);
    }

    fn extra_for(&self, kernel: &str) -> u32 {
        if !self.inner.armed.load(Ordering::Acquire) {
            return 0;
        }
        self.inner.extra.lock().get(kernel).copied().unwrap_or(0)
    }
}

/// The native engine. One per thread (by the [`Engine`] contract);
/// each engine owns a private [`BufferPool`], so a pool worker's scratch
/// slabs are reused across its calls without cross-worker contention.
pub struct NativeEngine {
    pool: BufferPool,
    fault: NativeFault,
}

impl NativeEngine {
    /// An engine with a fresh scratch pool and no interference.
    pub fn new() -> NativeEngine {
        NativeEngine { pool: BufferPool::new(), fault: NativeFault::new() }
    }

    /// An engine sharing an interference handle (factory construction).
    pub fn with_fault(fault: NativeFault) -> NativeEngine {
        NativeEngine { pool: BufferPool::new(), fault }
    }

    /// Scratch-pool counters (observability; asserted by tests).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

impl Engine for NativeEngine {
    fn compile(&self, variant: &Variant, _hlo_text: &str) -> Result<Box<dyn CompiledKernel>> {
        let cfg = KernelCfg::parse(variant).map_err(|e| Error::CompileFailed {
            variant: variant.id.clone(),
            msg: e.to_string(),
        })?;
        let output_shape = variant.output_shape()?;
        let out_len: usize = output_shape.iter().product();
        if out_len != cfg.output_len() {
            return Err(Error::CompileFailed {
                variant: variant.id.clone(),
                msg: format!(
                    "output signature {} disagrees with kernel output length {}",
                    variant.output, cfg.output_len()
                ),
            });
        }
        Ok(Box::new(NativeKernel {
            inner: Arc::new(NativeKernelState {
                variant_id: variant.id.clone(),
                kernel: variant.kernel.clone(),
                cfg,
                output_shape,
                pool: self.pool.clone(),
                fault: self.fault.clone(),
            }),
        }))
    }

    fn name(&self) -> &str {
        "native"
    }
}

/// Sharable executable state: the kernel config is `Copy`, the pool and
/// fault handles are `Arc`-backed, so the fast lane can publish native
/// kernels and run them from any application thread.
struct NativeKernelState {
    variant_id: String,
    kernel: String,
    cfg: KernelCfg,
    output_shape: Vec<usize>,
    pool: BufferPool,
    fault: NativeFault,
}

impl SharedKernel for NativeKernelState {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        let slices: Vec<&[f32]> = inputs.iter().map(HostTensor::data).collect();
        let mut out = vec![0.0f32; self.cfg.output_len()];
        // 1 + extra real passes: the interference handle models a
        // noisy-neighbour / thermal slowdown with genuine work.
        for _ in 0..=self.fault.extra_for(&self.kernel) {
            self.cfg.run(&slices, &mut out, &self.pool)?;
        }
        HostTensor::from_vec(&self.output_shape, out)
    }

    fn variant_id(&self) -> &str {
        &self.variant_id
    }
}

struct NativeKernel {
    inner: Arc<NativeKernelState>,
}

impl CompiledKernel for NativeKernel {
    fn execute(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        SharedKernel::execute(&*self.inner, inputs)
    }

    fn variant_id(&self) -> &str {
        &self.inner.variant_id
    }

    fn shared(&self) -> Option<Arc<dyn SharedKernel>> {
        Some(self.inner.clone())
    }
}

/// [`EngineFactory`] for native engines: every `create` builds a fresh
/// engine (private scratch pool) sharing one [`NativeFault`] handle, so
/// run-time interference reaches every pool worker. `pinned`
/// construction wraps engines in [`PinnedEngine`] — kernels refuse
/// `shared()`, forcing tuned traffic onto the worker pool exactly as a
/// thread-pinned backend would.
pub struct NativeEngineFactory {
    fault: NativeFault,
    pinned: bool,
}

impl NativeEngineFactory {
    /// Factory for plain native engines (kernels are shareable).
    pub fn new() -> NativeEngineFactory {
        NativeEngineFactory { fault: NativeFault::new(), pinned: false }
    }

    /// Factory whose engines refuse `shared()` (the PJRT shape).
    pub fn pinned() -> NativeEngineFactory {
        NativeEngineFactory { fault: NativeFault::new(), pinned: true }
    }

    /// The shared interference handle (clone before spawning the
    /// coordinator, inject mid-run).
    pub fn fault(&self) -> NativeFault {
        self.fault.clone()
    }
}

impl Default for NativeEngineFactory {
    fn default() -> Self {
        NativeEngineFactory::new()
    }
}

impl EngineFactory for NativeEngineFactory {
    fn create(&self) -> Result<Box<dyn Engine>> {
        let engine = NativeEngine::with_fault(self.fault.clone());
        Ok(if self.pinned {
            Box::new(PinnedEngine::new(Box::new(engine)))
        } else {
            Box::new(engine)
        })
    }

    fn name(&self) -> &str {
        if self.pinned {
            "native-pinned"
        } else {
            "native"
        }
    }
}

/// Matmul variant catalog: `(label, packed value)`. See
/// [`KernelCfg::parse`] for the packing.
pub const MATMUL_VARIANTS: &[(&str, i64)] = &[
    ("naive", 1),
    ("bt", 2),
    ("t8u1", 801),
    ("t16u1", 1601),
    ("t32u1", 3201),
    ("t64u1", 6401),
    ("t16u4", 1604),
    ("t32u4", 3204),
];

/// Saxpy variant catalog.
pub const SAXPY_VARIANTS: &[(&str, i64)] =
    &[("s8", 8), ("s32", 32), ("c256", 1256), ("c4096", 5096), ("full", 1049576)];

/// Reduce variant catalog.
pub const REDUCE_VARIANTS: &[(&str, i64)] =
    &[("seq", 1), ("lanes4", 4), ("lanes8", 8), ("lanes16", 16), ("lanes32", 32)];

/// Default matrix edges for the matmul family.
pub const DEFAULT_MATMUL_SIZES: &[i64] = &[64, 128, 192, 256];

/// Default vector lengths for the saxpy/reduce families.
pub const DEFAULT_VEC_SIZES: &[i64] = &[65_536, 1_048_576];

fn next_uniq() -> u64 {
    use std::sync::atomic::AtomicU64;
    // relaxed-counter: unique-suffix sequence, never synchronizes
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

/// Build a manifest over the native variant catalog: every matmul
/// variant at each of `matmul_sizes`, every saxpy/reduce variant at each
/// of `vec_sizes`. Stub HLO artifacts are written to a unique temp dir
/// so the compile cache's read path works unchanged; the native engine
/// compiles from the variant's packed value and ignores the HLO text.
pub fn native_manifest(matmul_sizes: &[i64], vec_sizes: &[i64]) -> Result<Manifest> {
    let dir = std::env::temp_dir().join(format!(
        "jitune-native-{}-{}",
        std::process::id(),
        next_uniq()
    ));
    std::fs::create_dir_all(&dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let mut entries = Vec::new();
    let mut push = |id: String, kernel: &str, param: &str, value: i64, label: &str, size: i64,
                    inputs: String, output: String, flops: i64|
     -> Result<()> {
        std::fs::write(dir.join(format!("{id}.hlo.txt")), "HloModule native_stub\n")
            .map_err(|e| Error::io(id.clone(), e))?;
        entries.push(format!(
            r#"{{"id":"{id}","kernel":"{kernel}","param":"{param}","value":{value},"label":"{label}","size":{size},"inputs":[{inputs}],"output":{output},"path":"{id}.hlo.txt","flops":{flops}}}"#
        ));
        Ok(())
    };
    for &n in matmul_sizes {
        for &(label, value) in MATMUL_VARIANTS {
            let sq = format!(r#""f32[{n},{n}]""#);
            push(
                format!("matmul.{label}.n{n}"),
                "matmul",
                "sched",
                value,
                label,
                n,
                format!("{sq},{sq}"),
                sq.clone(),
                2 * n * n * n,
            )?;
        }
    }
    for &len in vec_sizes {
        let vec_sig = format!(r#""f32[{len}]""#);
        for &(label, value) in SAXPY_VARIANTS {
            push(
                format!("saxpy.{label}.n{len}"),
                "saxpy",
                "access",
                value,
                label,
                len,
                format!(r#""f32[1]",{vec_sig},{vec_sig}"#),
                vec_sig.clone(),
                2 * len,
            )?;
        }
        for &(label, value) in REDUCE_VARIANTS {
            push(
                format!("reduce.{label}.n{len}"),
                "reduce",
                "lanes",
                value,
                label,
                len,
                vec_sig.clone(),
                r#""f32[1]""#.to_string(),
                len,
            )?;
        }
    }
    let text =
        format!(r#"{{"schema":1,"jax_version":"native","entries":[{}]}}"#, entries.join(","));
    Manifest::from_json_str(&text, dir)
}

/// [`native_manifest`] at the default size grid.
pub fn default_native_manifest() -> Result<Manifest> {
    native_manifest(DEFAULT_MATMUL_SIZES, DEFAULT_VEC_SIZES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::reference::{ref_matmul, ref_saxpy};

    #[test]
    fn manifest_loads_groups_and_artifacts_exist() {
        let m = native_manifest(&[16, 32], &[4096]).unwrap();
        // 2 matmul problems + saxpy + reduce
        assert_eq!(m.problems.len(), 4);
        assert_eq!(m.problem("matmul", 16).unwrap().variants.len(), MATMUL_VARIANTS.len());
        assert_eq!(m.problem("saxpy", 4096).unwrap().variants.len(), SAXPY_VARIANTS.len());
        assert_eq!(m.problem("reduce", 4096).unwrap().variants.len(), REDUCE_VARIANTS.len());
        for v in &m.variants {
            assert!(m.artifact_path(v).exists(), "missing artifact for {}", v.id);
        }
    }

    #[test]
    fn compiled_matmul_matches_oracle() {
        let m = native_manifest(&[24], &[]).unwrap();
        let engine = NativeEngine::new();
        let a = HostTensor::random(&[24, 24], 11);
        let b = HostTensor::random(&[24, 24], 12);
        let oracle = ref_matmul(&a, &b).unwrap();
        for v in &m.problem("matmul", 24).unwrap().variants {
            let k = engine.compile(v, "").unwrap();
            let out = k.execute(&[a.clone(), b.clone()]).unwrap();
            assert!(
                out.allclose(&oracle, 1e-4, 1e-5),
                "{} diverged from the f64 oracle",
                v.id
            );
        }
    }

    #[test]
    fn compiled_saxpy_matches_oracle() {
        let m = native_manifest(&[], &[1000]).unwrap();
        let engine = NativeEngine::new();
        let a = HostTensor::full(&[1], 2.5);
        let x = HostTensor::random(&[1000], 21);
        let y = HostTensor::random(&[1000], 22);
        let oracle = ref_saxpy(2.5, &x, &y).unwrap();
        for v in &m.problem("saxpy", 1000).unwrap().variants {
            let k = engine.compile(v, "").unwrap();
            let out = k.execute(&[a.clone(), x.clone(), y.clone()]).unwrap();
            assert!(out.allclose(&oracle, 1e-6, 1e-7), "{} diverged", v.id);
        }
    }

    #[test]
    fn shared_handles_follow_factory_mode() {
        let m = native_manifest(&[], &[256]).unwrap();
        let v = &m.problem("reduce", 256).unwrap().variants[0];
        let plain = NativeEngineFactory::new().create().unwrap();
        assert!(plain.compile(v, "").unwrap().shared().is_some());
        let pinned = NativeEngineFactory::pinned().create().unwrap();
        assert!(pinned.compile(v, "").unwrap().shared().is_none());
        assert_eq!(pinned.name(), "pinned(native)");
    }

    #[test]
    fn scratch_pool_recycles_across_calls() {
        let m = native_manifest(&[32], &[]).unwrap();
        let engine = NativeEngine::new();
        let v = m.variant("matmul.bt.n32").unwrap();
        let k = engine.compile(v, "").unwrap();
        let a = HostTensor::random(&[32, 32], 31);
        let b = HostTensor::random(&[32, 32], 32);
        for _ in 0..4 {
            k.execute(&[a.clone(), b.clone()]).unwrap();
        }
        let s = engine.pool_stats();
        assert_eq!(s.misses, 1, "only the first call may allocate scratch");
        assert_eq!(s.hits, 3, "subsequent calls recycle the transpose panel");
    }

    #[test]
    fn fault_injects_real_extra_work() {
        let m = native_manifest(&[96], &[]).unwrap();
        let factory = NativeEngineFactory::new();
        let fault = factory.fault();
        let engine = factory.create().unwrap();
        let v = m.variant("matmul.t32u1.n96").unwrap();
        let k = engine.compile(v, "").unwrap();
        let a = HostTensor::random(&[96, 96], 41);
        let b = HostTensor::random(&[96, 96], 42);
        let inputs = [a, b];
        let time = |k: &dyn CompiledKernel| {
            let t0 = std::time::Instant::now();
            for _ in 0..3 {
                k.execute(&inputs).unwrap();
            }
            t0.elapsed()
        };
        let healthy = time(k.as_ref());
        fault.slow_down("matmul", 7);
        let degraded = time(k.as_ref());
        fault.clear();
        assert!(
            degraded > healthy * 3,
            "8 passes should dominate 1: healthy={healthy:?} degraded={degraded:?}"
        );
    }

    #[test]
    fn compile_rejects_mismatched_output() {
        let mut v = native_manifest(&[16], &[]).unwrap().variant("matmul.naive.n16").unwrap().clone();
        v.output = "f32[4,4]".into();
        let engine = NativeEngine::new();
        assert!(matches!(
            engine.compile(&v, ""),
            Err(Error::CompileFailed { .. })
        ));
    }
}
