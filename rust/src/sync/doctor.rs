//! The lock doctor: process-wide lock-order and hold-time tracking.
//!
//! Compiled only under the `lock-doctor` feature. Every
//! [`TrackedMutex`](super::TrackedMutex) /
//! [`TrackedRwLock`](super::TrackedRwLock) acquisition reports here:
//!
//! * a **site** is a static label registered once per lock field
//!   (`"coordinator.pool.routes"`), shared by all instances of that
//!   field;
//! * each thread keeps a stack of currently held sites;
//! * acquiring site `B` while holding site `A` inserts the directed
//!   edge `A → B` into a global site-order graph;
//! * any cycle in that graph is a potential ABBA deadlock — two
//!   threads interleaving the two orders would hang — and is recorded
//!   (deduplicated) and logged via `log::warn!` the moment the closing
//!   edge appears, even if the run itself never deadlocked;
//! * a guard held longer than [`set_hold_threshold`] (default 100 ms)
//!   is recorded as a [`HoldViolation`] when dropped.
//!
//! Same-site edges (`A → A`) are deliberately not recorded: acquiring
//! two instances of the same site class in a fixed instance order
//! (e.g. the pool's per-shard queues during work stealing) is an
//! ordered same-class pattern, not an order inversion the graph can
//! judge — and the pool only ever holds one shard queue at a time
//! anyway.
//!
//! The registry is process-global so integration tests exercise the
//! whole coordinator stack; [`reset`] clears observations (but keeps
//! site registrations, which live as long as the process).

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::mutex_lock;

/// Index of a registered lock site in the global registry.
pub type SiteId = usize;

/// A cycle in the lock-order graph: site labels along the cycle, with
/// `path.first() == path.last()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LockCycle {
    /// Labels along the cycle, closed (first element repeated last).
    pub path: Vec<String>,
}

/// A guard that stayed held past the configured threshold.
#[derive(Debug, Clone)]
pub struct HoldViolation {
    /// Label of the lock site.
    pub site: String,
    /// How long the guard was held.
    pub held_for: Duration,
}

#[derive(Default)]
struct Registry {
    labels: Vec<&'static str>,
    by_label: HashMap<&'static str, SiteId>,
    /// Adjacency: `edges[from]` lists sites acquired while `from` held.
    edges: Vec<Vec<SiteId>>,
    edge_set: HashSet<(SiteId, SiteId)>,
    cycles: Vec<Vec<SiteId>>,
    cycle_keys: HashSet<Vec<SiteId>>,
    violations: Vec<HoldViolation>,
    hold_threshold: Option<Duration>,
}

impl Registry {
    fn threshold(&self) -> Duration {
        self.hold_threshold.unwrap_or_else(|| Duration::from_millis(100))
    }
}

fn registry() -> &'static Mutex<Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Registry::default()))
}

thread_local! {
    /// Sites currently held by this thread, in acquisition order.
    static HELD: RefCell<Vec<SiteId>> = const { RefCell::new(Vec::new()) };
}

/// Register (or look up) the site id for `label`.
pub fn site_id(label: &'static str) -> SiteId {
    let mut reg = mutex_lock(registry());
    if let Some(&id) = reg.by_label.get(label) {
        return id;
    }
    let id = reg.labels.len();
    reg.labels.push(label);
    reg.by_label.insert(label, id);
    reg.edges.push(Vec::new());
    id
}

/// Record order edges from every currently held site to `site`, and
/// check each *new* edge for a cycle. Called before the real blocking
/// acquisition (or, for condvar re-acquisition, right after the wait
/// returns — the held set is identical at both points).
pub fn before_acquire(site: SiteId) {
    let held: Vec<SiteId> = HELD.with(|h| h.borrow().clone());
    if held.is_empty() {
        return;
    }
    let mut reg = mutex_lock(registry());
    let mut seen = HashSet::new();
    for &from in &held {
        // Same-site self-edges are an ordered same-class pattern, not
        // an inversion — see module docs.
        if from == site || !seen.insert(from) {
            continue;
        }
        if reg.edge_set.insert((from, site)) {
            reg.edges[from].push(site);
            check_cycle(&mut reg, from, site);
        }
    }
}

/// After inserting `from → to`, search for a path `to → … → from`; if
/// one exists the new edge closed a cycle.
fn check_cycle(reg: &mut Registry, from: SiteId, to: SiteId) {
    let mut path = vec![to];
    let mut visited = HashSet::new();
    if !dfs(reg, to, from, &mut path, &mut visited) {
        return;
    }
    // Cycle as sites: from → to → … → from.
    let mut cycle = vec![from];
    cycle.extend(path);
    let key = canonical(&cycle);
    if !reg.cycle_keys.insert(key) {
        return;
    }
    let labels: Vec<String> = cycle.iter().map(|&s| reg.labels[s].to_string()).collect();
    log::warn!("lock-doctor: lock-order cycle detected: {}", labels.join(" -> "));
    reg.cycles.push(cycle);
}

fn dfs(
    reg: &Registry,
    node: SiteId,
    target: SiteId,
    path: &mut Vec<SiteId>,
    visited: &mut HashSet<SiteId>,
) -> bool {
    if node == target {
        return true;
    }
    if !visited.insert(node) {
        return false;
    }
    for &next in &reg.edges[node] {
        path.push(next);
        if dfs(reg, next, target, path, visited) {
            return true;
        }
        path.pop();
    }
    false
}

/// Canonical dedup key for a closed cycle: the distinct node sequence
/// rotated so the smallest site id leads.
fn canonical(cycle: &[SiteId]) -> Vec<SiteId> {
    let nodes = &cycle[..cycle.len() - 1]; // drop the closing repeat
    let min_pos = nodes
        .iter()
        .enumerate()
        .min_by_key(|(_, &s)| s)
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut key = Vec::with_capacity(nodes.len());
    key.extend_from_slice(&nodes[min_pos..]);
    key.extend_from_slice(&nodes[..min_pos]);
    key
}

/// Record the acquisition of `site` on this thread; the returned token
/// keeps it on the held stack until dropped.
pub fn acquired(site: SiteId) -> Held {
    HELD.with(|h| h.borrow_mut().push(site));
    Held { site, since: Instant::now() }
}

/// A held-lock token: created by [`acquired`], pops the thread's held
/// stack (and checks hold time) on drop.
pub struct Held {
    site: SiteId,
    since: Instant,
}

impl Held {
    /// The site this token tracks (used by condvar wait to re-register
    /// the re-acquisition).
    pub(super) fn site(&self) -> SiteId {
        self.site
    }
}

impl Drop for Held {
    fn drop(&mut self) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|&s| s == self.site) {
                held.remove(pos);
            }
        });
        let held_for = self.since.elapsed();
        let mut reg = mutex_lock(registry());
        if held_for > reg.threshold() {
            let site = reg.labels[self.site].to_string();
            log::warn!("lock-doctor: {site} held for {held_for:?} (over threshold)");
            reg.violations.push(HoldViolation { site, held_for });
        }
    }
}

/// All lock-order cycles observed so far (deduplicated).
pub fn cycles() -> Vec<LockCycle> {
    let reg = mutex_lock(registry());
    reg.cycles
        .iter()
        .map(|cycle| LockCycle {
            path: cycle.iter().map(|&s| reg.labels[s].to_string()).collect(),
        })
        .collect()
}

/// All hold-time violations observed so far.
pub fn hold_violations() -> Vec<HoldViolation> {
    mutex_lock(registry()).violations.clone()
}

/// Set the held-too-long reporting threshold (default 100 ms).
pub fn set_hold_threshold(threshold: Duration) {
    mutex_lock(registry()).hold_threshold = Some(threshold);
}

/// Clear observed edges, cycles and violations. Site registrations are
/// kept — they are cached in live lock instances for the life of the
/// process.
pub fn reset() {
    let mut reg = mutex_lock(registry());
    for adj in &mut reg.edges {
        adj.clear();
    }
    reg.edge_set.clear();
    reg.cycles.clear();
    reg.cycle_keys.clear();
    reg.violations.clear();
}
