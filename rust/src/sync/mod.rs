//! Tracked synchronization primitives: the repo's only lock types.
//!
//! Everything in `coordinator/`, `hub/` and `runtime/` synchronizes
//! through [`TrackedMutex`], [`TrackedRwLock`] and [`TrackedCondvar`]
//! instead of the raw `std::sync` types (enforced by `jitune-lint`
//! rule L001). The wrappers buy three things:
//!
//! 1. **Poison tolerance.** Every acquisition folds in
//!    `unwrap_or_else(|e| e.into_inner())`: a panicking worker must
//!    never wedge the serving path, and the coordinator's state types
//!    are written so any interrupted update leaves them consistent.
//!    This replaces the old `mutex_lock`/`read_lock`/`write_lock`
//!    helpers that were duplicated in `coordinator::mod` (the raw
//!    helpers remain available here for the rare raw-lock need inside
//!    `sync/` itself).
//! 2. **Lock-order deadlock detection** (the *lock doctor*). With the
//!    `lock-doctor` cargo feature enabled, every lock carries a static
//!    site label (e.g. `"coordinator.pool.routes"`); acquisitions
//!    maintain a per-thread stack of held sites and a global
//!    site-order graph, and any cycle in that graph — a potential
//!    ABBA deadlock, even one that never actually deadlocked in the
//!    run — is recorded and logged with the full label path. See
//!    [`doctor`].
//! 3. **Held-too-long reporting.** The doctor also records any guard
//!    held longer than a configurable threshold, catching slow work
//!    (compiles, measurements) accidentally moved under a serve-path
//!    lock.
//!
//! With the feature **off** (the default, including release serving
//! builds) the wrappers are transparent newtypes: no extra fields
//! (`repr(transparent)`), `#[inline]` passthrough methods, zero
//! allocation, zero atomics — the compiled code is identical to using
//! `std::sync` directly.
//!
//! # Usage
//!
//! ```
//! use jitune::sync::TrackedMutex;
//! let counter = TrackedMutex::new("docs.example.counter", 0u64);
//! *counter.lock() += 1;
//! assert_eq!(*counter.lock(), 1);
//! ```
//!
//! Run the tracked test suite with
//! `cargo test --features lock-doctor --test lock_doctor`.

use std::fmt;
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

pub use std::sync::WaitTimeoutResult;

#[cfg(feature = "lock-doctor")]
pub mod doctor;

/// Poison-tolerant raw mutex acquisition. Prefer [`TrackedMutex`]; this
/// exists for raw `std::sync` locks inside `sync/` itself and for code
/// that must interoperate with externally owned locks.
pub fn mutex_lock<T>(lock: &Mutex<T>) -> MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant raw read acquisition. See [`mutex_lock`].
pub fn read_lock<T>(lock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant raw write acquisition. See [`mutex_lock`].
pub fn write_lock<T>(lock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(|e| e.into_inner())
}

/// A [`std::sync::Mutex`] with a site label, poison-tolerant
/// acquisition, and (under the `lock-doctor` feature) lock-order
/// tracking.
#[cfg_attr(not(feature = "lock-doctor"), repr(transparent))]
pub struct TrackedMutex<T> {
    inner: Mutex<T>,
    #[cfg(feature = "lock-doctor")]
    site: doctor::SiteId,
}

impl<T> TrackedMutex<T> {
    /// Wrap `value` in a mutex registered under `label`. Labels are
    /// dotted paths naming the lock *site* (one per field, not per
    /// instance): every shard queue of every pool shares
    /// `"coordinator.pool.shard"`, which is exactly what makes
    /// order-graph cycles meaningful across instances.
    #[inline]
    pub fn new(label: &'static str, value: T) -> Self {
        #[cfg(not(feature = "lock-doctor"))]
        let _ = label;
        TrackedMutex {
            inner: Mutex::new(value),
            #[cfg(feature = "lock-doctor")]
            site: doctor::site_id(label),
        }
    }

    /// Acquire, recovering from poison (see module docs).
    #[inline]
    pub fn lock(&self) -> TrackedMutexGuard<'_, T> {
        #[cfg(feature = "lock-doctor")]
        doctor::before_acquire(self.site);
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        TrackedMutexGuard {
            inner,
            #[cfg(feature = "lock-doctor")]
            held: doctor::acquired(self.site),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for TrackedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TrackedMutex").finish_non_exhaustive()
    }
}

/// Guard returned by [`TrackedMutex::lock`]. Intentionally has no
/// `Drop` impl of its own so [`TrackedCondvar::wait`] can destructure
/// it; release bookkeeping lives in the field types.
pub struct TrackedMutexGuard<'a, T> {
    inner: MutexGuard<'a, T>,
    #[cfg(feature = "lock-doctor")]
    held: doctor::Held,
}

impl<T> std::ops::Deref for TrackedMutexGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for TrackedMutexGuard<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// A [`std::sync::RwLock`] with a site label, poison-tolerant
/// acquisition, and (under `lock-doctor`) lock-order tracking. Reads
/// and writes share one site: the order graph tracks *site* order, and
/// a read-then-write cycle is just as much a deadlock risk as
/// write-then-write.
#[cfg_attr(not(feature = "lock-doctor"), repr(transparent))]
pub struct TrackedRwLock<T> {
    inner: RwLock<T>,
    #[cfg(feature = "lock-doctor")]
    site: doctor::SiteId,
}

impl<T> TrackedRwLock<T> {
    /// Wrap `value` in an rwlock registered under `label` (see
    /// [`TrackedMutex::new`] for labeling conventions).
    #[inline]
    pub fn new(label: &'static str, value: T) -> Self {
        #[cfg(not(feature = "lock-doctor"))]
        let _ = label;
        TrackedRwLock {
            inner: RwLock::new(value),
            #[cfg(feature = "lock-doctor")]
            site: doctor::site_id(label),
        }
    }

    /// Shared acquisition, recovering from poison.
    #[inline]
    pub fn read(&self) -> TrackedReadGuard<'_, T> {
        #[cfg(feature = "lock-doctor")]
        doctor::before_acquire(self.site);
        let inner = self.inner.read().unwrap_or_else(|e| e.into_inner());
        TrackedReadGuard {
            inner,
            #[cfg(feature = "lock-doctor")]
            _held: doctor::acquired(self.site),
        }
    }

    /// Exclusive acquisition, recovering from poison.
    #[inline]
    pub fn write(&self) -> TrackedWriteGuard<'_, T> {
        #[cfg(feature = "lock-doctor")]
        doctor::before_acquire(self.site);
        let inner = self.inner.write().unwrap_or_else(|e| e.into_inner());
        TrackedWriteGuard {
            inner,
            #[cfg(feature = "lock-doctor")]
            _held: doctor::acquired(self.site),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for TrackedRwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TrackedRwLock").finish_non_exhaustive()
    }
}

/// Guard returned by [`TrackedRwLock::read`].
pub struct TrackedReadGuard<'a, T> {
    inner: RwLockReadGuard<'a, T>,
    #[cfg(feature = "lock-doctor")]
    _held: doctor::Held,
}

impl<T> std::ops::Deref for TrackedReadGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

/// Guard returned by [`TrackedRwLock::write`].
pub struct TrackedWriteGuard<'a, T> {
    inner: RwLockWriteGuard<'a, T>,
    #[cfg(feature = "lock-doctor")]
    _held: doctor::Held,
}

impl<T> std::ops::Deref for TrackedWriteGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for TrackedWriteGuard<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// A [`std::sync::Condvar`] paired with [`TrackedMutex`]. Waiting
/// releases the mutex, so under `lock-doctor` the wait drops the held
/// token for the park and re-registers the acquisition (with a fresh
/// order check) when the wait returns — a parked worker never shows up
/// as "holding" its queue lock.
#[cfg_attr(not(feature = "lock-doctor"), repr(transparent))]
pub struct TrackedCondvar {
    inner: Condvar,
}

impl TrackedCondvar {
    /// A fresh condvar.
    #[inline]
    pub fn new() -> Self {
        TrackedCondvar { inner: Condvar::new() }
    }

    /// Block until notified, recovering from poison.
    #[inline]
    pub fn wait<'a, T>(&self, guard: TrackedMutexGuard<'a, T>) -> TrackedMutexGuard<'a, T> {
        #[cfg(feature = "lock-doctor")]
        {
            let TrackedMutexGuard { inner, held } = guard;
            let site = held.site();
            drop(held); // parked threads hold nothing
            let inner = self.inner.wait(inner).unwrap_or_else(|e| e.into_inner());
            doctor::before_acquire(site);
            TrackedMutexGuard { inner, held: doctor::acquired(site) }
        }
        #[cfg(not(feature = "lock-doctor"))]
        {
            let TrackedMutexGuard { inner } = guard;
            TrackedMutexGuard { inner: self.inner.wait(inner).unwrap_or_else(|e| e.into_inner()) }
        }
    }

    /// Block until notified or `dur` elapses, recovering from poison.
    #[inline]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: TrackedMutexGuard<'a, T>,
        dur: Duration,
    ) -> (TrackedMutexGuard<'a, T>, WaitTimeoutResult) {
        #[cfg(feature = "lock-doctor")]
        {
            let TrackedMutexGuard { inner, held } = guard;
            let site = held.site();
            drop(held);
            let (inner, timed_out) =
                self.inner.wait_timeout(inner, dur).unwrap_or_else(|e| e.into_inner());
            doctor::before_acquire(site);
            (TrackedMutexGuard { inner, held: doctor::acquired(site) }, timed_out)
        }
        #[cfg(not(feature = "lock-doctor"))]
        {
            let TrackedMutexGuard { inner } = guard;
            let (inner, timed_out) =
                self.inner.wait_timeout(inner, dur).unwrap_or_else(|e| e.into_inner());
            (TrackedMutexGuard { inner }, timed_out)
        }
    }

    /// Wake one waiter.
    #[inline]
    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    /// Wake all waiters.
    #[inline]
    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl Default for TrackedCondvar {
    fn default() -> Self {
        TrackedCondvar::new()
    }
}

impl fmt::Debug for TrackedCondvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TrackedCondvar").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_basic() {
        let m = TrackedMutex::new("sync.test.mutex_basic", 1u32);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 2);
    }

    #[test]
    fn rwlock_basic() {
        let l = TrackedRwLock::new("sync.test.rwlock_basic", vec![1, 2]);
        assert_eq!(l.read().len(), 2);
        l.write().push(3);
        assert_eq!(*l.read(), vec![1, 2, 3]);
    }

    #[test]
    fn condvar_roundtrip() {
        let pair = Arc::new((
            TrackedMutex::new("sync.test.condvar_flag", false),
            TrackedCondvar::new(),
        ));
        let pair2 = Arc::clone(&pair);
        let t = std::thread::Builder::new()
            .name("sync-test-notifier".into())
            .spawn(move || {
                *pair2.0.lock() = true;
                pair2.1.notify_one();
            })
            .unwrap();
        let mut flag = pair.0.lock();
        while !*flag {
            let (g, _timed_out) = pair.1.wait_timeout(flag, Duration::from_millis(50));
            flag = g;
        }
        assert!(*flag);
        t.join().unwrap();
    }

    #[test]
    fn poisoned_mutex_recovers() {
        let m = Arc::new(TrackedMutex::new("sync.test.poison", 7u32));
        let m2 = Arc::clone(&m);
        let t = std::thread::Builder::new()
            .name("sync-test-poisoner".into())
            .spawn(move || {
                let _g = m2.lock();
                panic!("poison the lock");
            })
            .unwrap();
        assert!(t.join().is_err());
        assert_eq!(*m.lock(), 7);
    }
}
