//! Minimal command-line parser (clap is unavailable offline).
//!
//! Grammar: `jitune <subcommand> [--flag value]... [--switch]...`.
//! Flags are declared up front so typos fail fast with a usage message.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declaration of one accepted flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// Whether the flag takes a value (`--iters 100`) or is a switch.
    pub takes_value: bool,
    /// Help text.
    pub help: &'static str,
}

/// A parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    /// The subcommand (first positional).
    pub command: String,
    /// Flag values (`--key value`).
    pub flags: BTreeMap<String, String>,
    /// Present switches (`--verbose`).
    pub switches: Vec<String>,
    /// Remaining positionals after the subcommand.
    pub positionals: Vec<String>,
}

impl Parsed {
    /// Flag value as string.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Flag with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer flag with default.
    pub fn i64_or(&self, name: &str, default: i64) -> Result<i64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} `{v}` is not an integer"))),
        }
    }

    /// Switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Parse `args` (without argv[0]) against the accepted flags.
pub fn parse(args: &[String], specs: &[FlagSpec]) -> Result<Parsed> {
    let mut parsed = Parsed::default();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| Error::Config(format!("unknown flag --{name}")))?;
            if spec.takes_value {
                let value = it
                    .next()
                    .ok_or_else(|| Error::Config(format!("--{name} requires a value")))?;
                parsed.flags.insert(name.to_string(), value.clone());
            } else {
                parsed.switches.push(name.to_string());
            }
        } else if parsed.command.is_empty() {
            parsed.command = arg.clone();
        } else {
            parsed.positionals.push(arg.clone());
        }
    }
    Ok(parsed)
}

/// Render a usage block from flag specs.
pub fn usage(program: &str, commands: &[(&str, &str)], specs: &[FlagSpec]) -> String {
    let mut out = format!("usage: {program} <command> [flags]\n\ncommands:\n");
    for (name, help) in commands {
        out.push_str(&format!("  {name:<12} {help}\n"));
    }
    out.push_str("\nflags:\n");
    for s in specs {
        let name = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        out.push_str(&format!("  {name:<20} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "iters", takes_value: true, help: "iterations" },
            FlagSpec { name: "verbose", takes_value: false, help: "chatty" },
        ]
    }

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_flags_switches_positionals() {
        let p = parse(&args("tune --iters 100 --verbose matmul"), &specs()).unwrap();
        assert_eq!(p.command, "tune");
        assert_eq!(p.i64_or("iters", 0).unwrap(), 100);
        assert!(p.has("verbose"));
        assert_eq!(p.positionals, vec!["matmul"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&args("x --nope"), &specs()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&args("x --iters"), &specs()).is_err());
    }

    #[test]
    fn bad_integer_rejected() {
        let p = parse(&args("x --iters abc"), &specs()).unwrap();
        assert!(p.i64_or("iters", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let p = parse(&args("x"), &specs()).unwrap();
        assert_eq!(p.i64_or("iters", 7).unwrap(), 7);
        assert_eq!(p.str_or("missing", "d"), "d");
        assert!(!p.has("verbose"));
    }

    #[test]
    fn usage_lists_everything() {
        let u = usage("jitune", &[("tune", "tune a kernel")], &specs());
        assert!(u.contains("tune a kernel"));
        assert!(u.contains("--iters"));
        assert!(u.contains("--verbose"));
    }
}
