//! **Ablation: measurement metric** — the paper's §3.2 notes that the
//! measurement function "can be overloaded and any other measurement
//! function can be used to count any other metric, such as energy
//! consumption".
//!
//! (a) On the real engine, verifies that `rdtsc` (paper default) and
//! wall-clock tuning agree on the winner — cycles and seconds are
//! monotonically related on a fixed machine.
//! (b) On the mock engine, builds a *divergent* energy model (the fast
//! variant draws disproportionate power) and shows the energy-tuned
//! winner differs from the time-tuned one: the metric is a real policy
//! input, not a cosmetic knob.
//!
//! Output: stdout table + `target/figures/ablation_metric.csv`.

use std::time::Duration;

use jitune::autotuner::{Autotuner, EnergyModel, Metric, Rdtsc, WallClock};
use jitune::coordinator::{Dispatcher, KernelRegistry};
use jitune::report::bench::{artifacts_or_skip, autotuned_run};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::runtime::PjrtEngine;
use jitune::tensor::HostTensor;
use jitune::util::chart;

/// An energy metric whose measured joules depend on which variant runs —
/// emulating per-variant power draw: cost = seconds × watts(variant).
/// Set up so the *slower* variant wins on energy.
struct VariantPowerModel {
    clock: WallClock,
}

impl Metric for VariantPowerModel {
    fn name(&self) -> &'static str {
        "variant_power_model"
    }
    fn unit(&self) -> &'static str {
        "J"
    }
    fn begin(&self) -> u64 {
        self.clock.begin()
    }
    fn end(&self, begin: u64) -> f64 {
        // The dispatcher measures around execute(); the mock's fast
        // variant (v1, ~100µs) is modelled at 300W, the slow one (v0,
        // ~150µs) at 50W → energy ranking inverts the time ranking.
        // We approximate "which variant ran" by the duration regime.
        let secs = self.clock.end(begin);
        let watts = if secs < 125e-6 { 300.0 } else { 50.0 };
        secs * watts
    }
}

fn mock_dispatcher(metric: Box<dyn Metric>) -> Dispatcher {
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(150))
        .with_cost("kern.v1.n8", Duration::from_micros(100));
    let dir = std::env::temp_dir().join(format!("jitune-metric-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut entries = Vec::new();
    for i in 0..2 {
        let id = format!("kern.v{i}.n8");
        std::fs::write(dir.join(format!("{id}.hlo.txt")), "HloModule dummy\n").unwrap();
        entries.push(format!(
            r#"{{"id":"{id}","kernel":"kern","param":"p","value":{i},"label":"v{i}",
                "size":8,"inputs":["f32[8,8]"],"output":"f32[8,8]","path":"{id}.hlo.txt","flops":1}}"#
        ));
    }
    let manifest = jitune::manifest::Manifest::from_json_str(
        &format!(r#"{{"schema":1,"jax_version":"x","entries":[{}]}}"#, entries.join(",")),
        dir,
    )
    .unwrap();
    Dispatcher::with(
        KernelRegistry::new(manifest),
        Box::new(MockEngine::new(spec)),
        Autotuner::sweep(),
        metric,
    )
}

fn main() {
    jitune::util::logging::init();
    let mut rows = Vec::new();

    println!("== Ablation: tuning metric ==\n");

    // (a) real engine: rdtsc vs wall clock vs constant-power energy
    if let Some(manifest) = artifacts_or_skip("ablation_metric(real)") {
        println!("real engine, matmul_order n=256 — winner per metric:");
        for (name, metric) in [
            ("wall_clock", Box::new(WallClock::new()) as Box<dyn Metric>),
            ("rdtsc", Box::new(Rdtsc)),
            ("energy(65W const)", Box::new(EnergyModel::new(65.0))),
        ] {
            let registry = KernelRegistry::new(manifest.clone());
            let engine = PjrtEngine::cpu().expect("pjrt");
            let mut d =
                Dispatcher::with(registry, Box::new(engine), Autotuner::sweep(), metric);
            autotuned_run(&mut d, "matmul_order", 256, 5, 42).expect("run");
            let winner = d.tuned_value("matmul_order", 256);
            println!("  {name:<20} winner index: {winner:?}");
            rows.push(vec!["real".into(), name.into(), format!("{winner:?}")]);
        }
        println!("  (monotone metrics must agree on a fixed machine — same winner)\n");
    }

    // (b) mock engine with divergent per-variant power
    println!("mock engine, inverted power model — metric changes the winner:");
    let mut d_time = mock_dispatcher(Box::new(WallClock::new()));
    let inputs = [HostTensor::zeros(&[8, 8])];
    for _ in 0..4 {
        d_time.call("kern", &inputs).unwrap();
    }
    let time_winner = d_time.tuned_value("kern", 8);

    let mut d_energy =
        mock_dispatcher(Box::new(VariantPowerModel { clock: WallClock::new() }));
    for _ in 0..4 {
        d_energy.call("kern", &inputs).unwrap();
    }
    let energy_winner = d_energy.tuned_value("kern", 8);
    println!("  wall_clock           winner: v{time_winner:?}");
    println!("  variant power model  winner: v{energy_winner:?}");
    rows.push(vec!["mock".into(), "wall_clock".into(), format!("{time_winner:?}")]);
    rows.push(vec!["mock".into(), "variant_power".into(), format!("{energy_winner:?}")]);
    assert_ne!(
        time_winner, energy_winner,
        "divergent power model must flip the winner"
    );
    println!(
        "\nfast-but-hungry loses under the energy objective — the overloadable \
         metric is a real policy input (paper §3.2)."
    );

    let header = ["engine", "metric", "winner"];
    jitune::report::write_figure_file("ablation_metric.csv", &chart::csv(&header, &rows))
        .expect("csv");
    println!("wrote target/figures/ablation_metric.csv");
}
