//! **Cold-start caller latency** — inline vs background exploration.
//!
//! The serve/explore split's claim: once any runnable variant exists,
//! callers never pay exploration. Inline tuning makes early callers run
//! candidate compile+measure themselves, so the cold-start latency tail
//! is compile-sized; background mode serves the default variant while
//! candidates compile+measure on pool workers under the duty-cycle
//! budget, so the cold tail stays execution-sized.
//!
//! Two series over a synthetic manifest + mock engine (runs anywhere,
//! including CI `--smoke`):
//!
//! 1. **Cold-start p50/p99**: a caller stream from process start, inline
//!    vs background (5% budget), plus the steady-state distribution once
//!    tuned. Acceptance: background cold p99 within 2x steady p99, while
//!    inline's cold p99 is compile-bound (>10x steady on this mock).
//! 2. **Time-to-tuned**: background exploration (sequentialized by the
//!    budget's in-flight pipeline) vs inline fused rounds with 4
//!    co-scheduled callers. Acceptance: within 1.5x.
//!
//! Results land in `BENCH_COLD_START.json` at the repository root —
//! full runs only: `--smoke` never writes the committed file, and every
//! figure is validated as a real (finite, positive) measurement before
//! the write, so placeholder-shaped output cannot get in silently.
//! Env knob: `JITUNE_BENCH_COLD_CALLS` (cold samples, default 1000).

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use jitune::coordinator::{
    Coordinator, Dispatcher, ExploreOptions, KernelRegistry, PoolOptions, ServerOptions,
};
use jitune::runtime::mock::{MockEngineFactory, MockSpec};
use jitune::runtime::EngineFactory;
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;
use jitune::util::json::{n, s, Value};
use jitune::util::stats::percentile;

const KERNEL: &str = "kern";
const SIZE: i64 = 8;
const VARIANTS: usize = 8;
const WORKERS: usize = 2;
const BUDGET_PCT: f64 = 5.0;

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

/// Latency profile for the p50/p99 series: compile dominates execution
/// (the paper's regime). The default variant (v0, what background mode
/// serves cold) is slightly worse than the winner (v4) — the cost of
/// serving untuned, as opposed to the cost of exploring inline.
fn latency_spec() -> MockSpec {
    let mut spec = MockSpec::default().with_compile_cost(Duration::from_millis(5));
    for i in 0..VARIANTS {
        let dist = (i as i64 - (VARIANTS / 2) as i64).unsigned_abs();
        spec = spec.with_cost(
            &format!("{KERNEL}.v{i}.n{SIZE}"),
            Duration::from_micros(500 + 25 * dist),
        );
    }
    spec
}

/// Cheap profile for the time-to-tuned series: total explore cost fits
/// one duty-cycle window, so the comparison measures scheduling, not
/// budget starvation.
fn ttt_spec() -> MockSpec {
    let mut spec = MockSpec::default().with_compile_cost(Duration::from_micros(300));
    for i in 0..VARIANTS {
        let dist = (i as i64 - (VARIANTS / 2) as i64).unsigned_abs();
        spec = spec.with_cost(
            &format!("{KERNEL}.v{i}.n{SIZE}"),
            Duration::from_micros(50 + 15 * dist),
        );
    }
    spec
}

/// Coordinator over a pinned mock pool (every call pays the same channel
/// hop in both modes). `budget` = None is inline exploration.
fn coordinator(spec: MockSpec, budget: Option<f64>) -> Coordinator {
    let factory = Arc::new(MockEngineFactory::pinned(spec));
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    let opts = ServerOptions {
        pool: Some(PoolOptions::new(factory).with_workers(WORKERS)),
        explore_budget: budget.map(ExploreOptions::percent),
        ..ServerOptions::default()
    };
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), leader_factory.create()?))
        },
        opts,
    )
    .expect("coordinator")
}

/// Caller-observed latency (µs) of `calls` back-to-back calls.
fn measure_stream(coord: &Coordinator, calls: usize) -> Vec<f64> {
    let h = coord.handle();
    (0..calls)
        .map(|_| {
            let t0 = Instant::now();
            h.call(KERNEL, inputs()).expect("bench call");
            t0.elapsed().as_secs_f64() * 1e6
        })
        .collect()
}

fn wait_tuned(coord: &Coordinator) {
    let h = coord.handle();
    let t0 = Instant::now();
    while h.tuned_value(KERNEL, SIZE).expect("tuned_value").is_none() {
        assert!(t0.elapsed() < Duration::from_secs(30), "tuning never converged");
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// Time-to-tuned, background mode: one call plans the problem, then the
/// pool explores under the budget while we poll.
fn ttt_background() -> Duration {
    let coord = coordinator(ttt_spec(), Some(BUDGET_PCT));
    let t0 = Instant::now();
    coord.handle().call(KERNEL, inputs()).expect("plan call");
    wait_tuned(&coord);
    t0.elapsed()
}

/// Time-to-tuned, inline fused: lock-step waves of 4 co-scheduled
/// callers (the PR-5 fused-round path).
fn ttt_inline_fused() -> Duration {
    const CALLERS: usize = 4;
    let coord = coordinator(ttt_spec(), None);
    let t0 = Instant::now();
    loop {
        let barrier = Arc::new(Barrier::new(CALLERS));
        let joins: Vec<_> = (0..CALLERS)
            .map(|_| {
                let h = coord.handle();
                let b = barrier.clone();
                std::thread::spawn(move || {
                    b.wait();
                    h.call(KERNEL, inputs()).expect("wave call");
                })
            })
            .collect();
        for j in joins {
            j.join().expect("wave thread");
        }
        if coord.handle().tuned_value(KERNEL, SIZE).expect("tuned_value").is_some() {
            return t0.elapsed();
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "fused tuning never converged");
    }
}

fn series(label: &str, samples: &[f64]) -> (f64, f64) {
    let (p50, p99) = (percentile(samples, 50.0), percentile(samples, 99.0));
    println!("  {label:<26} p50 {p50:9.1}us   p99 {p99:9.1}us   ({} calls)", samples.len());
    (p50, p99)
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cold_calls = std::env::var("JITUNE_BENCH_COLD_CALLS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 200 } else { 1000 });
    let steady_calls = cold_calls / 2;
    println!(
        "== cold-start caller latency: inline vs background exploration \
         ({VARIANTS} variants, {WORKERS} workers, {BUDGET_PCT}% budget{}) ==\n",
        if smoke { ", smoke" } else { "" }
    );

    // Series 1: cold-start stream from process start, then steady state.
    println!("cold-start stream ({cold_calls} calls from first call):");
    let inline_coord = coordinator(latency_spec(), None);
    let inline_cold = measure_stream(&inline_coord, cold_calls);
    let (inline_p50, inline_p99) = series("inline explore", &inline_cold);

    let bg_coord = coordinator(latency_spec(), Some(BUDGET_PCT));
    let bg_cold = measure_stream(&bg_coord, cold_calls);
    let (bg_p50, bg_p99) = series("background explore", &bg_cold);

    wait_tuned(&bg_coord);
    let steady = measure_stream(&bg_coord, steady_calls);
    let (steady_p50, steady_p99) = series("steady state (tuned)", &steady);

    let bg_ratio = bg_p99 / steady_p99;
    let inline_ratio = inline_p99 / steady_p99;
    println!("\n  cold p99 over steady p99:  background {bg_ratio:.2}x   inline {inline_ratio:.2}x");

    // Series 2: time-to-tuned, background budget vs inline fused rounds.
    let ttt_bg = ttt_background();
    let ttt_inline = ttt_inline_fused();
    let ttt_ratio = ttt_bg.as_secs_f64() / ttt_inline.as_secs_f64();
    println!("\ntime-to-tuned:");
    println!("  inline fused (4 callers)   {:8.3}ms", ttt_inline.as_secs_f64() * 1e3);
    println!("  background (5% budget)     {:8.3}ms   ({ttt_ratio:.2}x)", ttt_bg.as_secs_f64() * 1e3);

    if smoke {
        // Smoke proves the harness runs; its small-sample figures are
        // not trajectory-grade, so the committed BENCH_COLD_START.json
        // is never touched from here (same policy as traffic_replay).
        println!("\nsmoke mode: skipping acceptance gates and BENCH_COLD_START.json write.");
        println!("cold_start_p99 done.");
        return;
    }

    // Acceptance gates: background cold tail stays serving-sized and
    // the budget does not slow tuning past 1.5x the fused path.
    assert!(
        bg_ratio <= 2.0,
        "background cold p99 must be within 2x steady p99, got {bg_ratio:.2}x"
    );
    assert!(
        ttt_ratio <= 1.5,
        "background time-to-tuned must be within 1.5x inline fused, got {ttt_ratio:.2}x"
    );

    // Refuse to emit anything that is not a real measurement — the
    // committed file once carried a placeholder, and nothing
    // placeholder-shaped may get back in silently.
    for (label, v) in [
        ("inline cold p50", inline_p50),
        ("inline cold p99", inline_p99),
        ("background cold p50", bg_p50),
        ("background cold p99", bg_p99),
        ("steady p50", steady_p50),
        ("steady p99", steady_p99),
        ("time-to-tuned background ms", ttt_bg.as_secs_f64() * 1e3),
        ("time-to-tuned inline ms", ttt_inline.as_secs_f64() * 1e3),
    ] {
        assert!(
            v.is_finite() && v > 0.0,
            "refusing to emit placeholder output: {label} = {v} is not a real measurement"
        );
    }

    let json = Value::Obj(vec![
        ("bench".into(), s("cold_start_p99")),
        ("smoke".into(), Value::Bool(false)),
        (
            "config".into(),
            Value::Obj(vec![
                ("variants".into(), n(VARIANTS as f64)),
                ("workers".into(), n(WORKERS as f64)),
                ("budget_pct".into(), n(BUDGET_PCT)),
                ("cold_calls".into(), n(cold_calls as f64)),
                ("compile_ms".into(), n(5.0)),
            ]),
        ),
        (
            "inline".into(),
            Value::Obj(vec![
                ("cold_p50_us".into(), n(inline_p50)),
                ("cold_p99_us".into(), n(inline_p99)),
                ("time_to_tuned_ms".into(), n(ttt_inline.as_secs_f64() * 1e3)),
            ]),
        ),
        (
            "background".into(),
            Value::Obj(vec![
                ("cold_p50_us".into(), n(bg_p50)),
                ("cold_p99_us".into(), n(bg_p99)),
                ("time_to_tuned_ms".into(), n(ttt_bg.as_secs_f64() * 1e3)),
            ]),
        ),
        (
            "steady".into(),
            Value::Obj(vec![
                ("p50_us".into(), n(steady_p50)),
                ("p99_us".into(), n(steady_p99)),
            ]),
        ),
        (
            "ratios".into(),
            Value::Obj(vec![
                ("background_cold_p99_over_steady".into(), n(bg_ratio)),
                ("inline_cold_p99_over_steady".into(), n(inline_ratio)),
                ("ttt_background_over_inline_fused".into(), n(ttt_ratio)),
            ]),
        ),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_COLD_START.json");
    jitune::util::atomic_write(&out, &json.to_json_pretty()).expect("write bench json");
    println!("\nwrote {}", out.display());
    println!("cold_start_p99 done.");
}
