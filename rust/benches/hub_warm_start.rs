//! **Hub warm-start** — time-to-first-tuned-call and explore-iteration
//! count for a cold process vs processes warm-started from the
//! tuned-state hub: the fleet-scale version of the paper's Fig. 3-5
//! amortization claim. Online tuning amortizes its overhead over one
//! process's calls; the hub amortizes it over the *fleet* — every member
//! after the first skips exploration entirely.
//!
//! Four series, all driven on the mock engine with sleep-based
//! execution (each explore iteration really costs wall time, as a JIT
//! compile + measurement would). An in-process broker stands in for
//! `jitune hub serve`:
//!
//! 1. **cold** — the first member pays the full candidate sweep and
//!    seeds the hub.
//! 2. **warm** — fleet members spawned against the live broker adopt
//!    the winner at spawn: zero explores each.
//! 3. **restart** — the broker is stopped and rebound from its persist
//!    directory; a member spawned against the *restarted* broker still
//!    sees zero explores (durability: no acked publish was lost).
//! 4. **shipped** — the broker's map is exported as a tuned-cache
//!    artifact (`jitune state export`), and a hub-less process
//!    cold-boots from that file alone: zero explores (the "ship the
//!    cache" deployment path).
//!
//! Results land in `BENCH_HUB.json` at the repository root — but only
//! from a full run: `--smoke` exercises every series with tiny knobs
//! and never touches the committed file, and a non-finite/non-positive
//! figure aborts the run instead of being written. No placeholder can
//! get in silently. Explore-count assertions (cold sweeps all, every
//! other series explores zero) hold in both modes.
//!
//! Also emits `target/figures/hub_warm_start.{csv,txt,json}`.
//!
//! Env knobs: `JITUNE_BENCH_VARIANTS` (candidate count, default 8),
//! `JITUNE_BENCH_EXEC_US` (per-iteration execution sleep, default 300),
//! `JITUNE_BENCH_FLEET` (warm processes measured, default 4).

use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions};
use jitune::hub::{
    artifact_json, BrokerOptions, HubClient, HubOptions, HubServer, HubStopHandle, PersistOptions,
    ReplayReport,
};
use jitune::report::Figure;
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;
use jitune::util::chart::Series;
use jitune::util::json::{n, s, Value};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// A broker we can stop and rebind, as `jitune hub serve` restarting.
struct Broker {
    stop: HubStopHandle,
    join: Option<std::thread::JoinHandle<()>>,
    replay: ReplayReport,
}

impl Broker {
    fn start(opts: BrokerOptions) -> Broker {
        let server = HubServer::bind_with(opts).expect("bind hub");
        let replay = server.replay_report();
        let stop = server.stop_handle();
        let join = Some(server.spawn());
        Broker { stop, join, replay }
    }

    fn shutdown(mut self) {
        self.stop.stop();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Variant i costs (i+1) * exec_us: a real spread for the sweep to
/// rank; v0 is the eventual winner.
fn member_spec(variants: usize, exec_us: u64) -> MockSpec {
    let mut spec = MockSpec::default().with_sleep_exec();
    for i in 0..variants {
        spec = spec.with_cost(
            &format!("kern.v{i}.n8"),
            Duration::from_micros((i as u64 + 1) * exec_us),
        );
    }
    spec
}

fn spawn_member(socket: &std::path::Path, variants: usize, exec_us: u64) -> Coordinator {
    let spec = member_spec(variants, exec_us);
    let hub = HubOptions::at(socket);
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", variants, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { hub: Some(hub), ..ServerOptions::default() },
    )
    .expect("spawn coordinator")
}

/// A hub-less process booting from a shipped cache artifact alone.
fn spawn_shipped(artifact: &std::path::Path, variants: usize, exec_us: u64) -> Coordinator {
    let spec = member_spec(variants, exec_us);
    let artifact = artifact.to_path_buf();
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", variants, &[8])?;
            let registry = KernelRegistry::new(manifest);
            let mut d = Dispatcher::new(registry, Box::new(MockEngine::new(spec)));
            d.load_state(&artifact)?;
            Ok(d)
        },
        ServerOptions::default(),
    )
    .expect("spawn shipped-cache coordinator")
}

/// Drive one member to its first steady-state call; returns
/// (time-to-tuned seconds, explore iterations, calls made).
fn time_to_tuned(coord: &Coordinator) -> (f64, i64, usize) {
    let h = coord.handle();
    let t0 = Instant::now();
    let mut calls = 0usize;
    loop {
        let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call");
        calls += 1;
        if o.route == CallRoute::Tuned {
            break;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let explored = h
        .stats_json()
        .expect("stats_json")
        .get("kernels")
        .and_then(|k| k.get("kern"))
        .and_then(|k| k.get("explored"))
        .and_then(Value::as_i64)
        .unwrap_or(-1);
    (dt, explored, calls)
}

/// Abort instead of emitting a figure that is not a real measurement.
fn require_real(figures: &[(&str, f64)]) {
    for (label, v) in figures {
        assert!(
            v.is_finite() && *v > 0.0,
            "refusing to emit placeholder output: {label} = {v} is not a real measurement"
        );
    }
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let variants = env_usize("JITUNE_BENCH_VARIANTS", if smoke { 4 } else { 8 });
    let exec_us = env_usize("JITUNE_BENCH_EXEC_US", if smoke { 60 } else { 300 }) as u64;
    let fleet = env_usize("JITUNE_BENCH_FLEET", if smoke { 2 } else { 4 });
    println!(
        "== hub warm-start: time to first tuned call, cold vs hub-warmed \
         ({variants} variants, {exec_us}us exec, fleet of {fleet}{}) ==",
        if smoke { ", smoke" } else { "" }
    );

    let socket = jitune::testutil::temp_path("hub-bench", "sock");
    let persist_dir = jitune::testutil::temp_path("hub-bench-persist", "d");
    let broker_opts = BrokerOptions::unix(&socket).with_persist(PersistOptions::at(&persist_dir));
    let broker = Broker::start(broker_opts.clone());

    // member 0 is cold: it pays the full sweep and seeds the hub
    let cold = spawn_member(&socket, variants, exec_us);
    let (cold_s, cold_explored, cold_calls) = time_to_tuned(&cold);
    println!(
        "  cold     explores={cold_explored:<3} calls={cold_calls:<3} \
         time_to_tuned={:.1}ms",
        cold_s * 1e3
    );
    assert_eq!(cold_explored, variants as i64, "cold start sweeps every candidate");

    // members 1..=fleet warm-start off the hub: zero explores each
    let mut rows = vec![vec![
        "cold".to_string(),
        cold_explored.to_string(),
        format!("{:.3}", cold_s * 1e3),
    ]];
    let mut results = vec![Value::Obj(vec![
        ("mode".into(), s("cold")),
        ("explores".into(), n(cold_explored as f64)),
        ("time_to_tuned_ms".into(), n(cold_s * 1e3)),
    ])];
    let mut warm_points = Vec::new();
    let mut warm_total_s = 0.0;
    for i in 1..=fleet {
        let member = spawn_member(&socket, variants, exec_us);
        let (warm_s, warm_explored, warm_calls) = time_to_tuned(&member);
        println!(
            "  warm#{i}   explores={warm_explored:<3} calls={warm_calls:<3} \
             time_to_tuned={:.1}ms",
            warm_s * 1e3
        );
        assert_eq!(warm_explored, 0, "a warm-started process skips exploration entirely");
        warm_total_s += warm_s;
        warm_points.push((i as f64, warm_s * 1e3));
        rows.push(vec![
            format!("warm{i}"),
            warm_explored.to_string(),
            format!("{:.3}", warm_s * 1e3),
        ]);
        results.push(Value::Obj(vec![
            ("mode".into(), s(format!("warm{i}"))),
            ("explores".into(), n(warm_explored as f64)),
            ("time_to_tuned_ms".into(), n(warm_s * 1e3)),
        ]));
    }
    drop(cold);

    // restart series: bounce the broker and rebind it from its persist
    // directory; a fresh member against the restarted broker must still
    // warm-start — no acked publish may be lost.
    broker.shutdown();
    let broker = Broker::start(broker_opts);
    let replayed = broker.replay.snapshot_entries + broker.replay.log_records;
    assert!(replayed > 0, "restarted broker must replay the seeded entries");
    let member = spawn_member(&socket, variants, exec_us);
    let (restart_s, restart_explored, restart_calls) = time_to_tuned(&member);
    println!(
        "  restart  explores={restart_explored:<3} calls={restart_calls:<3} \
         time_to_tuned={:.1}ms  (broker bounced, {replayed} records replayed)",
        restart_s * 1e3
    );
    assert_eq!(restart_explored, 0, "a broker restart must not cost the fleet a re-sweep");
    rows.push(vec![
        "restart".to_string(),
        restart_explored.to_string(),
        format!("{:.3}", restart_s * 1e3),
    ]);
    results.push(Value::Obj(vec![
        ("mode".into(), s("restart")),
        ("explores".into(), n(restart_explored as f64)),
        ("time_to_tuned_ms".into(), n(restart_s * 1e3)),
    ]));
    drop(member);

    // shipped series: export the broker's map as a cache artifact and
    // cold-boot a hub-less process from the file alone.
    let artifact = jitune::testutil::temp_path("hub-bench-cache", "json");
    let entries = HubClient::connect(HubOptions::at(&socket))
        .expect("connect for export")
        .pull_all()
        .expect("pull for export");
    jitune::util::atomic_write(&artifact, &artifact_json(&entries).to_json_pretty())
        .expect("write cache artifact");
    let shipped = spawn_shipped(&artifact, variants, exec_us);
    let (ship_s, ship_explored, ship_calls) = time_to_tuned(&shipped);
    println!(
        "  shipped  explores={ship_explored:<3} calls={ship_calls:<3} \
         time_to_tuned={:.1}ms  (cold boot from exported artifact, no hub)",
        ship_s * 1e3
    );
    assert_eq!(ship_explored, 0, "a cold boot from a shipped cache artifact explores nothing");
    rows.push(vec![
        "shipped".to_string(),
        ship_explored.to_string(),
        format!("{:.3}", ship_s * 1e3),
    ]);
    results.push(Value::Obj(vec![
        ("mode".into(), s("shipped")),
        ("explores".into(), n(ship_explored as f64)),
        ("time_to_tuned_ms".into(), n(ship_s * 1e3)),
    ]));

    let warm_mean_s = warm_total_s / fleet as f64;
    let speedup = if warm_mean_s > 0.0 { cold_s / warm_mean_s } else { 0.0 };
    println!(
        "\n  fleet amortization: {} explore iterations total for {} processes \
         (one cold sweep); warm mean {:.1}ms vs cold {:.1}ms = {speedup:.1}x faster to tuned",
        cold_explored,
        fleet + 1,
        warm_mean_s * 1e3,
        cold_s * 1e3,
    );

    let fig = Figure {
        stem: "hub_warm_start".into(),
        title: "time to first tuned call (ms): cold sweep vs hub warm-start".into(),
        header: vec!["mode".into(), "explores".into(), "time_to_tuned_ms".into()],
        rows,
        series: vec![
            Series::new("cold", vec![(0.0, cold_s * 1e3)]),
            Series::new("warm", warm_points),
            Series::new("restart", vec![(0.0, restart_s * 1e3)]),
            Series::new("shipped", vec![(0.0, ship_s * 1e3)]),
        ],
        log_y: false,
    };
    let rendered = fig.emit().expect("emit");
    println!("{rendered}");

    broker.shutdown();
    let _ = std::fs::remove_file(&socket);
    let _ = std::fs::remove_file(&artifact);
    let _ = std::fs::remove_dir_all(&persist_dir);

    if smoke {
        // The PR gate proves every series runs end to end (the explore
        // counts asserted above are exact in any mode); tiny knobs make
        // the timings meaningless, and the committed file must only
        // ever hold full-run measurements.
        println!("smoke mode: skipping BENCH_HUB.json write.");
        println!("hub_warm_start done.");
        return;
    }

    require_real(&[
        ("cold time_to_tuned_ms", cold_s * 1e3),
        ("warm mean time_to_tuned_ms", warm_mean_s * 1e3),
        ("restart time_to_tuned_ms", restart_s * 1e3),
        ("shipped time_to_tuned_ms", ship_s * 1e3),
        ("speedup_to_tuned", speedup),
        ("replayed records", replayed as f64),
    ]);

    let json = Value::Obj(vec![
        ("bench".into(), s("hub_warm_start")),
        ("smoke".into(), Value::Bool(false)),
        (
            "config".into(),
            Value::Obj(vec![
                ("engine".into(), s("mock(sleep)")),
                ("variants".into(), n(variants as f64)),
                ("exec_us".into(), n(exec_us as f64)),
                ("fleet".into(), n(fleet as f64)),
            ]),
        ),
        (
            "summary".into(),
            Value::Obj(vec![
                ("cold_ms".into(), n(cold_s * 1e3)),
                ("warm_mean_ms".into(), n(warm_mean_s * 1e3)),
                ("restart_ms".into(), n(restart_s * 1e3)),
                ("shipped_ms".into(), n(ship_s * 1e3)),
                ("speedup_to_tuned".into(), n(speedup)),
                ("cold_explores".into(), n(cold_explored as f64)),
                ("warm_explores".into(), n(0.0)),
                ("restart_explores".into(), n(restart_explored as f64)),
                ("shipped_explores".into(), n(ship_explored as f64)),
                ("replayed_records".into(), n(replayed as f64)),
            ]),
        ),
        ("results".into(), Value::Arr(results.clone())),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_HUB.json");
    jitune::util::atomic_write(&out, &json.to_json_pretty()).expect("write bench json");
    println!("wrote {}", out.display());

    let report = Value::Obj(vec![
        ("bench".into(), s("hub_warm_start")),
        ("engine".into(), s("mock(sleep)")),
        ("variants".into(), n(variants as f64)),
        ("exec_us".into(), n(exec_us as f64)),
        ("fleet".into(), n(fleet as f64)),
        ("speedup_to_tuned".into(), n(speedup)),
        ("results".into(), Value::Arr(results)),
    ]);
    jitune::report::write_figure_file("hub_warm_start.json", &report.to_json_pretty())
        .expect("json");
    println!("wrote target/figures/hub_warm_start.{{csv,txt,json}}");
    println!("hub_warm_start done.");
}
