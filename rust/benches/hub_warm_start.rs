//! **Hub warm-start** — time-to-first-tuned-call and explore-iteration
//! count for a cold process vs a process warm-started from the
//! tuned-state hub: the fleet-scale version of the paper's Fig. 3-5
//! amortization claim. Online tuning amortizes its overhead over one
//! process's calls; the hub amortizes it over the *fleet* — every member
//! after the first skips exploration entirely.
//!
//! Runs on the mock engine with sleep-based execution (each explore
//! iteration really costs wall time, as a JIT compile + measurement
//! would). An in-process broker stands in for `jitune hub serve`.
//!
//! Output: stdout chart + `target/figures/hub_warm_start.{csv,txt,json}`.
//!
//! Env knobs: `JITUNE_BENCH_VARIANTS` (candidate count, default 8),
//! `JITUNE_BENCH_EXEC_US` (per-iteration execution sleep, default 300),
//! `JITUNE_BENCH_FLEET` (warm processes measured, default 4).

use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions};
use jitune::hub::{HubOptions, HubServer};
use jitune::report::Figure;
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;
use jitune::util::chart::Series;
use jitune::util::json::{n, s, Value};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn spawn_member(socket: &std::path::Path, variants: usize, exec_us: u64) -> Coordinator {
    // variant i costs (i+1) * exec_us: a real spread for the sweep to
    // rank; v0 is the eventual winner
    let mut spec = MockSpec::default().with_sleep_exec();
    for i in 0..variants {
        spec = spec.with_cost(
            &format!("kern.v{i}.n8"),
            Duration::from_micros((i as u64 + 1) * exec_us),
        );
    }
    let hub = HubOptions::at(socket);
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", variants, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { hub: Some(hub), ..ServerOptions::default() },
    )
    .expect("spawn coordinator")
}

/// Drive one member to its first steady-state call; returns
/// (time-to-tuned seconds, explore iterations, calls made).
fn time_to_tuned(coord: &Coordinator) -> (f64, i64, usize) {
    let h = coord.handle();
    let t0 = Instant::now();
    let mut calls = 0usize;
    loop {
        let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call");
        calls += 1;
        if o.route == CallRoute::Tuned {
            break;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let explored = h
        .stats_json()
        .expect("stats_json")
        .get("kernels")
        .and_then(|k| k.get("kern"))
        .and_then(|k| k.get("explored"))
        .and_then(Value::as_i64)
        .unwrap_or(-1);
    (dt, explored, calls)
}

fn main() {
    jitune::util::logging::init();
    let variants = env_usize("JITUNE_BENCH_VARIANTS", 8);
    let exec_us = env_usize("JITUNE_BENCH_EXEC_US", 300) as u64;
    let fleet = env_usize("JITUNE_BENCH_FLEET", 4);
    println!(
        "== hub warm-start: time to first tuned call, cold vs hub-warmed \
         ({variants} variants, {exec_us}us exec, fleet of {fleet}) =="
    );

    let socket = jitune::testutil::temp_path("hub-bench", "sock");
    HubServer::bind(&socket).expect("bind hub").spawn();

    // member 0 is cold: it pays the full sweep and seeds the hub
    let cold = spawn_member(&socket, variants, exec_us);
    let (cold_s, cold_explored, cold_calls) = time_to_tuned(&cold);
    println!(
        "  cold   explores={cold_explored:<3} calls={cold_calls:<3} \
         time_to_tuned={:.1}ms",
        cold_s * 1e3
    );
    assert_eq!(cold_explored, variants as i64, "cold start sweeps every candidate");

    // members 1..=fleet warm-start off the hub: zero explores each
    let mut rows = vec![vec![
        "cold".to_string(),
        cold_explored.to_string(),
        format!("{:.3}", cold_s * 1e3),
    ]];
    let mut results = vec![Value::Obj(vec![
        ("mode".into(), s("cold")),
        ("explores".into(), n(cold_explored as f64)),
        ("time_to_tuned_ms".into(), n(cold_s * 1e3)),
    ])];
    let mut warm_points = Vec::new();
    let mut warm_total_s = 0.0;
    for i in 1..=fleet {
        let member = spawn_member(&socket, variants, exec_us);
        let (warm_s, warm_explored, warm_calls) = time_to_tuned(&member);
        println!(
            "  warm#{i} explores={warm_explored:<3} calls={warm_calls:<3} \
             time_to_tuned={:.1}ms",
            warm_s * 1e3
        );
        assert_eq!(warm_explored, 0, "a warm-started process skips exploration entirely");
        warm_total_s += warm_s;
        warm_points.push((i as f64, warm_s * 1e3));
        rows.push(vec![
            format!("warm{i}"),
            warm_explored.to_string(),
            format!("{:.3}", warm_s * 1e3),
        ]);
        results.push(Value::Obj(vec![
            ("mode".into(), s(format!("warm{i}"))),
            ("explores".into(), n(warm_explored as f64)),
            ("time_to_tuned_ms".into(), n(warm_s * 1e3)),
        ]));
    }

    let warm_mean_s = warm_total_s / fleet as f64;
    let speedup = if warm_mean_s > 0.0 { cold_s / warm_mean_s } else { 0.0 };
    println!(
        "\n  fleet amortization: {} explore iterations total for {} processes \
         (one cold sweep); warm mean {:.1}ms vs cold {:.1}ms = {speedup:.1}x faster to tuned",
        cold_explored,
        fleet + 1,
        warm_mean_s * 1e3,
        cold_s * 1e3,
    );

    let fig = Figure {
        stem: "hub_warm_start".into(),
        title: "time to first tuned call (ms): cold sweep vs hub warm-start".into(),
        header: vec!["mode".into(), "explores".into(), "time_to_tuned_ms".into()],
        rows,
        series: vec![
            Series::new("cold", vec![(0.0, cold_s * 1e3)]),
            Series::new("warm", warm_points),
        ],
        log_y: false,
    };
    let rendered = fig.emit().expect("emit");
    println!("{rendered}");

    let report = Value::Obj(vec![
        ("bench".into(), s("hub_warm_start")),
        ("engine".into(), s("mock(sleep)")),
        ("variants".into(), n(variants as f64)),
        ("exec_us".into(), n(exec_us as f64)),
        ("fleet".into(), n(fleet as f64)),
        ("speedup_to_tuned".into(), n(speedup)),
        ("results".into(), Value::Arr(results)),
    ]);
    jitune::report::write_figure_file("hub_warm_start.json", &report.to_json_pretty())
        .expect("json");
    println!("wrote target/figures/hub_warm_start.{{csv,txt,json}}");
    let _ = std::fs::remove_file(&socket);
}
