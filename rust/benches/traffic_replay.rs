//! **Traffic replay on the native engine** — real kernels, real traffic.
//!
//! Every other bench drives mock engines under uniform call loops. This
//! one grounds the scaling claims: the CPU-native engine's variants do
//! genuinely different machine work (tiling/unrolling, access patterns,
//! reduction trees), and the traffic generator replays a seeded
//! production-shaped trace (Zipfian popularity, shape churn, open-loop
//! bursts, mid-run interference) against the full coordinator stack —
//! fast lane + worker pool + background exploration + drift retuning.
//!
//! Three stages (full mode):
//!
//! 1. **Exhaustive sweep**: every matmul variant at the sweep size,
//!    measured directly on a native engine. Acceptance: >= 1.3x spread
//!    between worst and best variant (the tuner has something real to
//!    find).
//! 2. **Replay**: the Zipfian shape-churn trace through a live
//!    coordinator; mid-run the interference handle quadruples matmul
//!    work (drift). Reported: p50/p99 (overall/cold/steady),
//!    per-problem time-to-good, explore duty cycle, tuned-state size
//!    series.
//! 3. **Convergence**: the tuned winner's sweep-measured mean must be
//!    within noise (1.25x) of the exhaustive best.
//!
//! Results land in `BENCH_TRAFFIC.json` at the repository root — but
//! only from a full run whose figures validated as real measurements:
//! `--smoke` never touches the committed file, and a figure that comes
//! out non-finite or non-positive aborts the run instead of being
//! written. No placeholder can get in silently.
//!
//! Env knob: `JITUNE_BENCH_TRAFFIC_CALLS` (trace length, default 3000).

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{
    Coordinator, Dispatcher, DriftPolicy, ExploreOptions, KernelRegistry, PoolOptions,
    ServerOptions,
};
use jitune::manifest::Manifest;
use jitune::runtime::native::native_manifest;
use jitune::runtime::{Engine, EngineFactory, NativeEngine, NativeEngineFactory, NativeFault};
use jitune::traffic::{ReplayOptions, TrafficHarness, TrafficSpec};
use jitune::util::json::{n, s, Value};
use jitune::workload::inputs_for;

const WORKERS: usize = 2;
const BUDGET_PCT: f64 = 25.0;
const SWEEP_KERNEL: &str = "matmul";
const SWEEP_REPS: usize = 30;
const INPUT_SEED: u64 = 0xBEEF;

/// One matmul variant's exhaustive measurement.
struct SweepPoint {
    id: String,
    value: i64,
    mean_us: f64,
}

/// Measure every variant of the sweep problem directly on a fresh
/// native engine (no coordinator — this is the ground truth the tuner
/// is judged against).
fn sweep(manifest: &Manifest, size: i64) -> Vec<SweepPoint> {
    let engine = NativeEngine::new();
    let problem = manifest.problem(SWEEP_KERNEL, size).expect("sweep problem");
    let inputs = inputs_for(problem, INPUT_SEED);
    problem
        .variants
        .iter()
        .map(|v| {
            let kernel = engine.compile(v, "").expect("native compile");
            kernel.execute(&inputs).expect("sweep warmup");
            let t0 = Instant::now();
            for _ in 0..SWEEP_REPS {
                kernel.execute(&inputs).expect("sweep exec");
            }
            SweepPoint {
                id: v.id.clone(),
                value: v.value,
                mean_us: t0.elapsed().as_secs_f64() * 1e6 / SWEEP_REPS as f64,
            }
        })
        .collect()
}

/// Full coordinator over a pinned native factory: fast lane, worker
/// pool, background exploration under a duty-cycle budget, and a
/// fast-reacting drift policy (bench runs are seconds, not hours).
fn coordinator(manifest_sizes: (&[i64], &[i64])) -> (Coordinator, NativeFault) {
    let factory = Arc::new(NativeEngineFactory::pinned());
    let fault = factory.fault();
    let leader_factory: Arc<dyn EngineFactory> = factory.clone();
    let opts = ServerOptions {
        pool: Some(PoolOptions::new(factory).with_workers(WORKERS)),
        explore_budget: Some(
            ExploreOptions::percent(BUDGET_PCT).with_window(Duration::from_millis(50)),
        ),
        drift: Some(DriftPolicy {
            window: Duration::from_millis(100),
            min_samples: 16,
            ratio_threshold: 1.7,
            cooldown: Duration::from_secs(1),
            consecutive_windows: 2,
            ..DriftPolicy::default()
        }),
        ..ServerOptions::default()
    };
    let (matmul_sizes, vec_sizes) = (manifest_sizes.0.to_vec(), manifest_sizes.1.to_vec());
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = native_manifest(&matmul_sizes, &vec_sizes)?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), leader_factory.create()?))
        },
        opts,
    )
    .expect("coordinator");
    (coord, fault)
}

/// Poll (with keep-alive traffic) until the coordinator has a tuned
/// winner for `(kernel, size)`.
fn wait_tuned(coord: &Coordinator, manifest: &Manifest, kernel: &str, size: i64) -> i64 {
    let h = coord.handle();
    let inputs = inputs_for(manifest.problem(kernel, size).expect("problem"), INPUT_SEED);
    let t0 = Instant::now();
    loop {
        if let Some(value) = h.tuned_value(kernel, size).expect("tuned_value") {
            return value;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "{kernel}/n{size} never converged after the trace"
        );
        h.call(kernel, inputs.clone()).expect("keep-alive call");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Abort instead of emitting a figure that is not a real measurement.
fn require_real(figures: &[(&str, f64)]) {
    for (label, v) in figures {
        assert!(
            v.is_finite() && *v > 0.0,
            "refusing to emit placeholder output: {label} = {v} is not a real measurement"
        );
    }
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let calls: usize = std::env::var("JITUNE_BENCH_TRAFFIC_CALLS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 400 } else { 3000 });
    // Smoke keeps kernels tiny so the PR gate stays fast; full mode uses
    // sizes where variant choice visibly moves the needle.
    let (matmul_sizes, vec_sizes, sweep_size): (&[i64], &[i64], i64) = if smoke {
        (&[48], &[16_384], 48)
    } else {
        (&[64, 128], &[65_536], 128)
    };
    println!(
        "== traffic replay on the native engine ({WORKERS} workers, {BUDGET_PCT}% budget{}) ==\n",
        if smoke { ", smoke" } else { "" }
    );

    let manifest = native_manifest(matmul_sizes, vec_sizes).expect("native manifest");

    // Stage 1: exhaustive variant sweep (ground truth).
    println!("exhaustive sweep: {SWEEP_KERNEL} n={sweep_size}, {SWEEP_REPS} reps/variant:");
    let points = sweep(&manifest, sweep_size);
    for p in &points {
        println!("  {:<22} {:9.1}us", p.id, p.mean_us);
    }
    let best = points
        .iter()
        .min_by(|a, b| a.mean_us.partial_cmp(&b.mean_us).expect("finite means"))
        .expect("non-empty sweep");
    let worst = points
        .iter()
        .max_by(|a, b| a.mean_us.partial_cmp(&b.mean_us).expect("finite means"))
        .expect("non-empty sweep");
    let spread = worst.mean_us / best.mean_us;
    println!("  spread {spread:.2}x ({} .. {})\n", best.id, worst.id);

    // Stage 2: replay the production-shaped trace.
    let spec = TrafficSpec {
        calls,
        rps: if smoke { 2000.0 } else { 600.0 },
        zipf_s: 1.1,
        initial: 2,
        churn_every: calls / 6,
        burst: 3.0,
        burst_len: 60,
        drift_at: 0.5,
        seed: 42,
        clients: 4,
    };
    let (coord, fault) = coordinator((matmul_sizes, vec_sizes));
    let harness = TrafficHarness::new(&manifest, spec, INPUT_SEED).expect("harness");
    let inject = fault.clone();
    let opts = ReplayOptions {
        // Mid-run interference: matmul suddenly does 4x the work — the
        // drift monitor should notice the published winners degrading.
        drift_inject: Some(Arc::new(move || inject.slow_down(SWEEP_KERNEL, 3))),
        ..ReplayOptions::default()
    };
    let report = harness.run(&coord, &opts).expect("replay");
    print!("{}", report.render());
    assert_eq!(report.errors, 0, "replay must be error-free");

    // Stage 3: convergence. Clear the interference first so any
    // post-trace keep-alive tuning measures the same machine the sweep
    // did.
    fault.clear();
    let tuned = wait_tuned(&coord, &manifest, SWEEP_KERNEL, sweep_size);
    let tuned_point = points.iter().find(|p| p.value == tuned).expect("tuned variant in sweep");
    let convergence = tuned_point.mean_us / best.mean_us;
    println!(
        "\nconvergence: tuner picked {} ({:.1}us), exhaustive best {} ({:.1}us) -> {convergence:.2}x",
        tuned_point.id, tuned_point.mean_us, best.id, best.mean_us
    );

    if smoke {
        // The PR gate proves the stack runs end to end; tiny sizes make
        // timing-based acceptance too noisy to assert, and the committed
        // trajectory file must only ever hold full-run measurements.
        println!("\nsmoke mode: skipping acceptance gates and BENCH_TRAFFIC.json write.");
        println!("traffic_replay done.");
        return;
    }

    // Acceptance gates (ISSUE 8): the variants differ for real, and the
    // tuner found (within noise) the variant the exhaustive sweep found.
    assert!(spread >= 1.3, "variant spread must be >= 1.3x, got {spread:.2}x");
    assert!(
        convergence <= 1.25,
        "tuner must converge within noise of the exhaustive best, got {convergence:.2}x"
    );

    require_real(&[
        ("sweep best mean", best.mean_us),
        ("sweep spread", spread),
        ("replay p50", report.p50_us),
        ("replay p99", report.p99_us),
        ("steady p99", report.steady_p99_us),
        ("wall ms", report.wall_ms),
        ("tuned state bytes", report.tuned_state_bytes as f64),
    ]);

    let json = Value::Obj(vec![
        ("bench".into(), s("traffic_replay")),
        ("smoke".into(), Value::Bool(false)),
        (
            "config".into(),
            Value::Obj(vec![
                ("engine".into(), s("native")),
                (
                    "matmul_sizes".into(),
                    Value::Arr(matmul_sizes.iter().map(|&v| n(v as f64)).collect()),
                ),
                (
                    "vec_sizes".into(),
                    Value::Arr(vec_sizes.iter().map(|&v| n(v as f64)).collect()),
                ),
                ("workers".into(), n(WORKERS as f64)),
                ("budget_pct".into(), n(BUDGET_PCT)),
                ("sweep_size".into(), n(sweep_size as f64)),
                ("sweep_reps".into(), n(SWEEP_REPS as f64)),
            ]),
        ),
        (
            "sweep".into(),
            Value::Obj(vec![
                (
                    "variants".into(),
                    Value::Arr(
                        points
                            .iter()
                            .map(|p| {
                                Value::Obj(vec![
                                    ("id".into(), s(p.id.clone())),
                                    ("value".into(), n(p.value as f64)),
                                    ("mean_us".into(), n(p.mean_us)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("best".into(), s(best.id.clone())),
                ("worst".into(), s(worst.id.clone())),
                ("spread".into(), n(spread)),
            ]),
        ),
        (
            "convergence".into(),
            Value::Obj(vec![
                ("tuned".into(), s(tuned_point.id.clone())),
                ("tuned_mean_us".into(), n(tuned_point.mean_us)),
                ("best_mean_us".into(), n(best.mean_us)),
                ("over_best".into(), n(convergence)),
            ]),
        ),
        ("replay".into(), report.to_json()),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_TRAFFIC.json");
    jitune::util::atomic_write(&out, &json.to_json_pretty()).expect("write bench json");
    println!("\nwrote {}", out.display());
    println!("traffic_replay done.");
}
