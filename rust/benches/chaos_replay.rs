//! **Chaos replay** — production-shaped traffic with faults injected
//! mid-run, gated on the serving path's resilience contract.
//!
//! Every scenario replays a seeded trace against a live coordinator
//! while a [`FaultPlan`]-scheduled injection breaks something under it:
//!
//! | scenario       | injection                               | must hold |
//! |----------------|-----------------------------------------|-----------|
//! | `wedge`        | winner slows 250x (stuck accelerator)   | callers bounded by the deadline, no other errors |
//! | `error`        | winner's executions start failing       | breaker demotes to the fallback; bounded error burst |
//! | `worker_death` | a pool worker panics mid-job            | respawn absorbs it; no hung callers |
//! | `broker_down`  | the tuned-state hub broker goes away    | serving continues error-free |
//! | `overload`     | capacity crunch under a tight gate      | calls shed fast instead of queueing unboundedly |
//!
//! Cross-cutting gates: no scenario may hang (each replay must finish
//! within a generous wall-clock bound — a single stuck caller blows it),
//! error classes other than the injected one stay at zero, and where the
//! fault clears, post-clear p99 must recover to the healthy band.
//!
//! The mock engine drives every scenario: chaos needs *controllable*
//! faults (`LatencyFault::fail_execute` / `panic_once` / `set_scale`),
//! which real kernels cannot provide deterministically. Results land in
//! `BENCH_CHAOS.json` at the repository root — full runs only, after
//! every figure validated as a real measurement; `--smoke` runs the
//! same scenarios smaller, keeps the structural gates (no hangs, error
//! classes) and skips the timing gates plus the JSON write.
//!
//! Env knob: `JITUNE_BENCH_CHAOS_CALLS` (trace length per scenario).

use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{
    Coordinator, Dispatcher, KernelRegistry, PoolOptions, QuarantinePolicy, ServerOptions,
    ShedPolicy,
};
use jitune::hub::{HubAddr, HubOptions, HubServer};
use jitune::runtime::mock::{MockEngineFactory, MockSpec};
use jitune::runtime::EngineFactory;
use jitune::testutil::{synthetic_manifest, temp_path};
use jitune::traffic::{
    FaultInjection, FaultPlan, ReplayOptions, TrafficHarness, TrafficReport, TrafficSpec,
};
use jitune::util::json::{n, s, Value};

const KERNEL: &str = "kern";
const SIZE: i64 = 8;
const VARIANTS: usize = 3;
const RPS: f64 = 400.0;
const INPUT_SEED: u64 = 0xC0C0;
/// Post-clear p99 must come back under this (full mode): healthy calls
/// are sub-2ms sleeps, so 25ms covers queueing noise with a wide margin
/// while still catching a path that never recovered.
const RECOVERY_BOUND_US: f64 = 25_000.0;

/// Mock costs make variant 1 the clear winner (400us) with variant 2
/// the next-best fallback (1ms) — quarantine demotion is observable
/// from `tuned_value` alone. Sleep-modelled execution frees host CPUs,
/// so wedged calls park threads instead of burning cores.
fn chaos_spec() -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(2000))
        .with_cost("kern.v1.n8", Duration::from_micros(400))
        .with_cost("kern.v2.n8", Duration::from_micros(1000))
        .with_sleep_exec()
}

/// The tuner's healthy pick and the fault target in every scenario.
const WINNER: &str = "kern.v1.n8";
/// Tuning value of the next-best variant (the expected fallback).
const FALLBACK_VALUE: i64 = 2;

/// Single-problem trace: no churn, steady arrivals unless a scenario
/// asks for bursts.
fn traffic(calls: usize, clients: usize) -> TrafficSpec {
    TrafficSpec {
        calls,
        rps: RPS,
        zipf_s: 0.0,
        initial: 1,
        churn_every: 0,
        burst: 1.0,
        burst_len: 50,
        drift_at: 0.0,
        seed: 42,
        clients,
    }
}

/// Coordinator over mock engines. `workers > 0` attaches a pool of
/// pinned engines (kernels refuse `shared()`, so tuned calls take the
/// pool path); `workers == 0` with a plain factory serves tuned calls
/// on the caller-thread fast lane.
fn coordinator(spec: MockSpec, pinned: bool, workers: usize, mut opts: ServerOptions) -> Coordinator {
    let factory: Arc<dyn EngineFactory> = if pinned {
        Arc::new(MockEngineFactory::pinned(spec))
    } else {
        Arc::new(MockEngineFactory::new(spec))
    };
    if workers > 0 {
        opts.pool = Some(PoolOptions::new(factory.clone()).with_workers(workers));
    }
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE])?;
            Ok(Dispatcher::new(KernelRegistry::new(manifest), factory.create()?))
        },
        opts,
    )
    .expect("coordinator")
}

/// Wire a [`FaultPlan`]'s schedule to concrete injection closures.
fn injection(
    plan: &FaultPlan,
    calls: usize,
    fire: Arc<dyn Fn() + Send + Sync>,
    clear: Option<Arc<dyn Fn() + Send + Sync>>,
) -> FaultInjection {
    plan.validate().expect("fault plan");
    FaultInjection {
        label: plan.label(),
        at: plan.fire_index(calls),
        clear_at: plan.clear_index(calls),
        fire,
        clear,
    }
}

/// Replay with the no-hang gate: a single stuck caller keeps the
/// harness from joining its client and blows the wall-clock bound.
fn replay(
    name: &str,
    coord: &Coordinator,
    spec: &TrafficSpec,
    faults: Vec<FaultInjection>,
) -> TrafficReport {
    let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE]).expect("manifest");
    let harness = TrafficHarness::new(&manifest, spec.clone(), INPUT_SEED).expect("harness");
    let opts = ReplayOptions { faults, ..ReplayOptions::default() };
    let trace_secs = spec.calls as f64 / spec.rps;
    let bound = Duration::from_secs_f64(trace_secs * 6.0 + 20.0);
    let t0 = Instant::now();
    let report = harness.run(coord, &opts).expect("replay");
    let took = t0.elapsed();
    assert!(
        took < bound,
        "{name}: replay took {took:?} (bound {bound:?}) — a caller hung"
    );
    report
}

/// Poll `tuned_value` until the leader reports `want` (demotion and
/// fallback finalization run on leader ticks, not caller threads).
fn wait_tuned_value(name: &str, coord: &Coordinator, want: i64) {
    let h = coord.handle();
    let t0 = Instant::now();
    loop {
        if h.tuned_value(KERNEL, SIZE).expect("tuned_value") == Some(want) {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{name}: tuned value never reached {want} (got {:?})",
            h.tuned_value(KERNEL, SIZE)
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Errors that are neither sheds nor deadline misses — the classes a
/// scenario did *not* inject must stay at zero (or tightly bounded).
fn other_errors(r: &TrafficReport) -> usize {
    r.errors - r.shed - r.deadline_exceeded
}

/// One scenario's JSON row.
fn scenario_json(name: &str, plan: &FaultPlan, r: &TrafficReport) -> Value {
    let fault = r.faults.first();
    Value::Obj(vec![
        ("name".into(), s(name)),
        ("plan".into(), s(plan.label())),
        ("at".into(), n(plan.at)),
        ("clear".into(), n(plan.clear)),
        ("calls".into(), n(r.calls as f64)),
        ("errors".into(), n(r.errors as f64)),
        ("shed".into(), n(r.shed as f64)),
        ("deadline_exceeded".into(), n(r.deadline_exceeded as f64)),
        ("p50_us".into(), n(r.p50_us)),
        ("p99_us".into(), n(r.p99_us)),
        (
            "recovery_p99_us".into(),
            r.recovery_p99_us.map(n).unwrap_or(Value::Null),
        ),
        ("wall_ms".into(), n(r.wall_ms)),
        (
            "fired_ms".into(),
            fault.and_then(|f| f.fired_ms).map(n).unwrap_or(Value::Null),
        ),
        (
            "cleared_ms".into(),
            fault.and_then(|f| f.cleared_ms).map(n).unwrap_or(Value::Null),
        ),
    ])
}

/// Abort instead of emitting a figure that is not a real measurement.
fn require_real(figures: &[(String, f64)]) {
    for (label, v) in figures {
        assert!(
            v.is_finite() && *v > 0.0,
            "refusing to emit placeholder output: {label} = {v} is not a real measurement"
        );
    }
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let calls: usize = std::env::var("JITUNE_BENCH_CHAOS_CALLS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 240 } else { 1200 });
    println!(
        "== chaos replay on the mock engine ({calls} calls/scenario{}) ==\n",
        if smoke { ", smoke" } else { "" }
    );
    let mut rows = Vec::new();
    let mut figures = Vec::new();

    // -- wedge: the winner slows 250x mid-run; the per-call deadline
    // must bound every caller while the wedge holds, and nothing else
    // may error.
    {
        let plan = FaultPlan::parse("kind=wedge, at=0.3, clear=0.6, target=kern.v1.n8, factor=250")
            .expect("wedge plan");
        let spec = chaos_spec();
        let fault = spec.latency_fault.clone();
        let coord = coordinator(
            spec,
            true,
            2,
            ServerOptions { call_deadline: Some(Duration::from_millis(25)), ..Default::default() },
        );
        let fire = fault.clone();
        let factor = plan.factor;
        let clear = fault.clone();
        let report = replay(
            "wedge",
            &coord,
            &traffic(calls, 4),
            vec![injection(
                &plan,
                calls,
                Arc::new(move || fire.set_scale(WINNER, factor)),
                Some(Arc::new(move || clear.clear())),
            )],
        );
        print!("{}", report.render());
        assert!(
            report.deadline_exceeded > 0,
            "wedge: the deadline must trip while the winner is wedged"
        );
        assert_eq!(
            other_errors(&report),
            0,
            "wedge: only deadline misses may surface"
        );
        let recovery = report.recovery_p99_us.expect("wedge: clear scheduled, recovery reported");
        if !smoke {
            assert!(
                recovery < RECOVERY_BOUND_US,
                "wedge: post-clear p99 {recovery:.0}us must recover under {RECOVERY_BOUND_US}us"
            );
            figures.push(("wedge recovery p99".to_string(), recovery));
        }
        figures.push(("wedge p50".to_string(), report.p50_us));
        figures.push(("wedge wall ms".to_string(), report.wall_ms));
        rows.push(scenario_json("wedge", &plan, &report));
        println!();
    }

    // -- error: the winner's executions start failing; the quarantine
    // breaker must demote it and serve the next-best variant, keeping
    // the error burst to the breaker window.
    {
        let plan = FaultPlan::parse("kind=error, at=0.25, clear=0.65, target=kern.v1.n8")
            .expect("error plan");
        let spec = chaos_spec();
        let fault = spec.latency_fault.clone();
        let coord = coordinator(
            spec,
            false,
            0,
            ServerOptions {
                quarantine: Some(QuarantinePolicy {
                    window: Duration::from_millis(40),
                    min_samples: 4,
                    error_threshold: 0.4,
                    consecutive_windows: 1,
                    cooldown: Duration::ZERO,
                    quarantine_for: Duration::from_secs(60),
                }),
                ..Default::default()
            },
        );
        let fire = fault.clone();
        let clear = fault.clone();
        let report = replay(
            "error",
            &coord,
            &traffic(calls, 4),
            vec![injection(
                &plan,
                calls,
                Arc::new(move || fire.fail_execute(WINNER)),
                Some(Arc::new(move || clear.clear_error(WINNER))),
            )],
        );
        print!("{}", report.render());
        assert!(report.errors > 0, "error: the injected failures must surface at least once");
        assert!(
            report.errors <= calls / 4,
            "error: breaker must bound the burst, got {}/{} errors",
            report.errors,
            report.calls
        );
        assert_eq!(report.shed + report.deadline_exceeded, 0, "error: no shed/deadline classes");
        wait_tuned_value("error", &coord, FALLBACK_VALUE);
        let recovery = report.recovery_p99_us.expect("error: clear scheduled, recovery reported");
        if !smoke {
            assert!(
                recovery < RECOVERY_BOUND_US,
                "error: post-clear p99 {recovery:.0}us must recover under {RECOVERY_BOUND_US}us"
            );
            figures.push(("error recovery p99".to_string(), recovery));
        }
        figures.push(("error p50".to_string(), report.p50_us));
        figures.push(("error wall ms".to_string(), report.wall_ms));
        rows.push(scenario_json("error", &plan, &report));
        println!();
    }

    // -- worker_death: one pool worker panics mid-job (one-shot); the
    // pool must respawn it and the lost job's caller must be released
    // by the deadline instead of hanging on a dropped reply.
    {
        let plan = FaultPlan::parse("kind=worker_death, at=0.5, target=kern.v1.n8")
            .expect("worker_death plan");
        let spec = chaos_spec();
        let fault = spec.latency_fault.clone();
        let coord = coordinator(
            spec,
            true,
            2,
            ServerOptions { call_deadline: Some(Duration::from_millis(100)), ..Default::default() },
        );
        let fire = fault.clone();
        let report = replay(
            "worker_death",
            &coord,
            &traffic(calls, 4),
            vec![injection(
                &plan,
                calls,
                Arc::new(move || fire.panic_once(WINNER)),
                None,
            )],
        );
        print!("{}", report.render());
        assert!(
            report.errors <= 10,
            "worker_death: one dead worker may cost a handful of calls, got {}",
            report.errors
        );
        figures.push(("worker_death p50".to_string(), report.p50_us));
        figures.push(("worker_death wall ms".to_string(), report.wall_ms));
        rows.push(scenario_json("worker_death", &plan, &report));
        println!();
    }

    // -- broker_down: the tuned-state hub vanishes mid-run; serving
    // never depends on broker liveness, so callers must see nothing.
    {
        let plan = FaultPlan::parse("kind=broker_down, at=0.4").expect("broker_down plan");
        let socket = temp_path("chaos-hub", "sock");
        let server = HubServer::bind(&socket).expect("hub bind");
        let stop = server.stop_handle();
        let hub_join = server.spawn();
        let mut hub_opts = HubOptions::for_addr(HubAddr::Unix(socket.clone()));
        hub_opts.subscribe = true;
        let coord = coordinator(
            chaos_spec(),
            false,
            0,
            ServerOptions { hub: Some(hub_opts), ..Default::default() },
        );
        let report = replay(
            "broker_down",
            &coord,
            &traffic(calls, 4),
            vec![injection(&plan, calls, Arc::new(move || stop.stop()), None)],
        );
        print!("{}", report.render());
        assert_eq!(
            report.errors, 0,
            "broker_down: a dead broker must never surface to callers"
        );
        figures.push(("broker_down p50".to_string(), report.p50_us));
        figures.push(("broker_down wall ms".to_string(), report.wall_ms));
        rows.push(scenario_json("broker_down", &plan, &report));
        drop(coord);
        let _ = hub_join.join();
        let _ = std::fs::remove_file(&socket);
        println!();
    }

    // -- overload: every variant slows 25x under a tight admission gate
    // and six open-loop clients; excess calls must shed fast with
    // `Overloaded` instead of queueing unboundedly, and nothing else
    // may error.
    {
        let plan = FaultPlan::parse("kind=overload, at=0.35, clear=0.65, factor=25")
            .expect("overload plan");
        let spec = chaos_spec();
        let fault = spec.latency_fault.clone();
        let coord = coordinator(
            spec,
            true,
            1,
            ServerOptions {
                shed: Some(ShedPolicy {
                    max_inflight: 3,
                    max_queue_wait: Duration::from_millis(250),
                }),
                ..Default::default()
            },
        );
        let fire = fault.clone();
        let factor = plan.factor;
        let clear = fault.clone();
        let ids: Vec<String> = (0..VARIANTS).map(|i| format!("{KERNEL}.v{i}.n{SIZE}")).collect();
        let report = replay(
            "overload",
            &coord,
            &traffic(calls, 6),
            vec![injection(
                &plan,
                calls,
                Arc::new(move || {
                    for id in &ids {
                        fire.set_scale(id, factor);
                    }
                }),
                Some(Arc::new(move || clear.clear())),
            )],
        );
        print!("{}", report.render());
        assert!(report.shed > 0, "overload: the admission gate must shed under the crunch");
        assert_eq!(
            other_errors(&report) + report.deadline_exceeded,
            0,
            "overload: only sheds may surface"
        );
        let recovery =
            report.recovery_p99_us.expect("overload: clear scheduled, recovery reported");
        if !smoke {
            assert!(
                recovery < RECOVERY_BOUND_US,
                "overload: post-clear p99 {recovery:.0}us must recover under {RECOVERY_BOUND_US}us"
            );
            figures.push(("overload recovery p99".to_string(), recovery));
        }
        figures.push(("overload p50".to_string(), report.p50_us));
        figures.push(("overload wall ms".to_string(), report.wall_ms));
        rows.push(scenario_json("overload", &plan, &report));
        println!();
    }

    if smoke {
        println!("smoke mode: structural gates passed; skipping timing gates and BENCH_CHAOS.json.");
        println!("chaos_replay done.");
        return;
    }

    require_real(&figures);
    let json = Value::Obj(vec![
        ("bench".into(), s("chaos_replay")),
        ("smoke".into(), Value::Bool(false)),
        (
            "config".into(),
            Value::Obj(vec![
                ("engine".into(), s("mock")),
                ("calls_per_scenario".into(), n(calls as f64)),
                ("rps".into(), n(RPS)),
                ("variants".into(), n(VARIANTS as f64)),
                ("recovery_bound_us".into(), n(RECOVERY_BOUND_US)),
            ]),
        ),
        ("scenarios".into(), Value::Arr(rows)),
    ]);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_CHAOS.json");
    jitune::util::atomic_write(&out, &json.to_json_pretty()).expect("write bench json");
    println!("wrote {}", out.display());
    println!("chaos_replay done.");
}
