//! **Time-to-tuned** — serial vs fused exploration rounds.
//!
//! The paper's trade: compilation overhead on the first iterations is
//! amortized by the tuned steady state — so shrinking the explore phase
//! directly shrinks the overhead being amortized. With B co-scheduled
//! callers, a fused round measures up to B candidates at once, so a
//! sweep over V variants reaches `Phase::Tuned` in ~V/B leader rounds
//! instead of V.
//!
//! Two series over a synthetic manifest + mock engine (no artifacts
//! needed — this bench runs anywhere, including CI `--smoke`):
//!
//! 1. **Deterministic rounds**: leader rounds until `Phase::Tuned`,
//!    serial dispatch vs `Dispatcher::call_batch` at width 4 — the
//!    acceptance series (target ≥2x fewer rounds).
//! 2. **Wall clock through the coordinator**: a live leader hammered by
//!    4 caller threads in lock-step waves vs a single caller, with the
//!    `fused` counters from `stats_json()` printed as proof.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use jitune::autotuner::Phase;
use jitune::coordinator::{
    BatchOptions, Coordinator, Dispatcher, FusedStats, KernelRegistry, ServerOptions,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

const KERNEL: &str = "kern";
const SIZE: i64 = 8;
const VARIANTS: usize = 8;
const WIDTH: usize = 4;

/// V-shaped, well-separated costs: the winner sits mid-grid, exactly
/// like a block-size axis.
fn spec() -> MockSpec {
    let mut spec = MockSpec::default().with_compile_cost(Duration::from_micros(300));
    for i in 0..VARIANTS {
        let dist = (i as i64 - (VARIANTS / 2) as i64).unsigned_abs();
        spec = spec.with_cost(
            &format!("{KERNEL}.v{i}.n{SIZE}"),
            Duration::from_micros(80 + 120 * dist),
        );
    }
    spec
}

fn dispatcher() -> Dispatcher {
    let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE]).expect("synthetic manifest");
    Dispatcher::new(KernelRegistry::new(manifest), Box::new(MockEngine::new(spec())))
}

fn inputs() -> Vec<HostTensor> {
    vec![HostTensor::zeros(&[8, 8])]
}

fn rounds_to_tuned_serial() -> usize {
    let mut d = dispatcher();
    let mut rounds = 0;
    while d.phase(KERNEL, SIZE) != Some(Phase::Tuned) {
        d.call(KERNEL, &inputs()).expect("serial call");
        rounds += 1;
        assert!(rounds < 10_000, "serial tuning never converged");
    }
    rounds
}

fn rounds_to_tuned_fused(width: usize) -> (usize, FusedStats) {
    let mut d = dispatcher();
    let mut rounds = 0;
    while d.phase(KERNEL, SIZE) != Some(Phase::Tuned) {
        let batch: Vec<_> = (0..width).map(|_| inputs()).collect();
        for result in d.call_batch(KERNEL, batch) {
            result.expect("fused call");
        }
        rounds += 1;
        assert!(rounds < 10_000, "fused tuning never converged");
    }
    (rounds, d.stats().fused())
}

fn coordinator(max_batch: usize) -> Coordinator {
    let engine_spec = spec();
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest(KERNEL, VARIANTS, &[SIZE])?;
            Ok(Dispatcher::new(
                KernelRegistry::new(manifest),
                Box::new(MockEngine::new(engine_spec)),
            ))
        },
        ServerOptions { batch: BatchOptions { max_batch }, ..ServerOptions::default() },
    )
    .expect("coordinator")
}

/// Lock-step waves of `threads` concurrent callers until tuning
/// completes; returns (wall time, waves).
fn time_to_tuned(coord: &Coordinator, threads: usize) -> (Duration, usize) {
    let t0 = Instant::now();
    let mut waves = 0;
    loop {
        waves += 1;
        let barrier = Arc::new(Barrier::new(threads));
        let joins: Vec<_> = (0..threads)
            .map(|_| {
                let h = coord.handle();
                let b = barrier.clone();
                std::thread::spawn(move || {
                    b.wait();
                    h.call(KERNEL, inputs()).expect("wave call");
                })
            })
            .collect();
        for j in joins {
            j.join().expect("wave thread");
        }
        if coord.handle().tuned_value(KERNEL, SIZE).expect("tuned_value").is_some() {
            return (t0.elapsed(), waves);
        }
        assert!(waves < 1_000, "coordinator tuning never converged");
    }
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "== time-to-tuned: serial vs fused exploration rounds \
         ({VARIANTS} variants, width {WIDTH}{}) ==\n",
        if smoke { ", smoke" } else { "" }
    );

    // Series 1: deterministic leader rounds until Phase::Tuned.
    let serial = rounds_to_tuned_serial();
    let (fused, counters) = rounds_to_tuned_fused(WIDTH);
    let ratio = serial as f64 / fused as f64;
    println!("leader rounds to Phase::Tuned:");
    println!("  serial dispatch        {serial:4} rounds");
    println!("  fused  (width {WIDTH})       {fused:4} rounds   ({ratio:.1}x fewer)");
    println!(
        "  fused counters: rounds={} calls={} replicated={} rounds_saved={}\n",
        counters.fused_rounds,
        counters.fused_calls,
        counters.replicated_measurements,
        counters.explore_rounds_saved
    );
    assert!(
        ratio >= 2.0,
        "fused exploration must reach Tuned in >=2x fewer rounds \
         (serial {serial}, fused {fused})"
    );

    // Series 2: wall clock through the live coordinator.
    let serial_coord = coordinator(1);
    let (serial_wall, serial_waves) = time_to_tuned(&serial_coord, 1);
    let fused_coord = coordinator(16);
    let (fused_wall, fused_waves) = time_to_tuned(&fused_coord, WIDTH);
    println!("wall time to tuned through the coordinator:");
    println!(
        "  1 caller,  max_batch 1   {:8.3}ms  ({serial_waves} waves)",
        serial_wall.as_secs_f64() * 1e3
    );
    println!(
        "  {WIDTH} callers, max_batch 16  {:8.3}ms  ({fused_waves} waves)",
        fused_wall.as_secs_f64() * 1e3
    );
    let json = fused_coord.handle().stats_json().expect("stats_json");
    match json.get("fused") {
        Some(fused) => println!("  stats_json fused counters: {}", fused.to_json()),
        None => println!("  (no rounds fused through the coordinator this run)"),
    }
    if !smoke {
        // a second fused width for the curve: the saving scales with B
        let (fused8, _) = rounds_to_tuned_fused(8);
        println!("\n  fused (width 8)        {fused8:4} rounds");
    }
    println!("\ntime_to_tuned done.");
}
