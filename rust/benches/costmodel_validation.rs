//! **Eq. 1 / Eq. 2 validation** — plug measured compile cost `C` and
//! per-variant execution times `E_i` into the paper's §3.3 analytical
//! model and compare its predicted crossover `N*` against the crossover
//! actually measured from cumulative curves.
//!
//! Output: stdout table + `target/figures/costmodel.csv`.

use jitune::autotuner::cost_model::CostModel;
use jitune::report::bench::{artifacts_or_skip, autotuned_run, cumulative, fresh_dispatcher, steady_exec_time};
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::util::chart;
use jitune::workload::inputs_for;

const SIZES: &[i64] = &[64, 128, 256];
const WINDOW: usize = 120;

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("costmodel") else { return };

    println!("== Eq.1/Eq.2 cost-model validation on matmul loop orders ==\n");
    let mut rows = Vec::new();

    for &size in SIZES {
        let problem = manifest.problem("matmul_order", size).expect("problem").clone();
        let inputs = inputs_for(&problem, 42);
        let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));

        // measure C (mean over variants) and E_i (min over reps)
        let mut compile_costs = Vec::new();
        let mut exec_times = Vec::new();
        for v in &problem.variants {
            let c = cache.compile_timed(&manifest, v).expect("compile").as_secs_f64();
            compile_costs.push(c);
            let e = steady_exec_time(&manifest, &mut cache, v, &inputs, 5)
                .expect("exec")
                .as_secs_f64();
            exec_times.push(e);
        }
        let c_mean = compile_costs.iter().sum::<f64>() / compile_costs.len() as f64;
        let model = CostModel::new(c_mean, exec_times.clone());

        // measured autotuned curve + fixed curves, for empirical crossover
        let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
        let outcomes = autotuned_run(&mut d, "matmul_order", size, WINDOW, 42).expect("run");
        let auto_cum = cumulative(&outcomes);

        println!(
            "n={size}: C≈{:.1}ms  E=[{}]",
            c_mean * 1e3,
            exec_times.iter().map(|e| format!("{:.2}ms", e * 1e3)).collect::<Vec<_>>().join(", ")
        );
        for (p, v) in problem.variants.iter().enumerate() {
            let predicted = model.crossover(p);
            // empirical: first call where autotuned cumulative ≤ fixed
            let fixed_cum: Vec<f64> =
                (1..=WINDOW).map(|n| model.e_fixed(p, n)).collect();
            let measured = auto_cum
                .iter()
                .zip(&fixed_cum)
                .position(|(a, f)| a <= f);
            let pred_s = predicted.map(|n| n.to_string()).unwrap_or_else(|| "never".into());
            let meas_s = measured.map(|i| (i + 1).to_string()).unwrap_or_else(|| format!(">{WINDOW}"));
            println!("  vs fixed:{:<4} predicted N*={pred_s:<8} measured N*={meas_s}", v.label);
            rows.push(vec![
                size.to_string(),
                v.label.clone(),
                format!("{c_mean:.6}"),
                format!("{:.6}", exec_times[p]),
                pred_s,
                meas_s,
            ]);
        }
        // Eq.1 self-check against the measured cumulative at the window end
        let predicted_total = model.e_auto(WINDOW);
        let measured_total = *auto_cum.last().unwrap();
        let err = (predicted_total - measured_total).abs() / measured_total * 100.0;
        println!(
            "  Eq.1 total @ {WINDOW} calls: predicted {:.1}ms, measured {:.1}ms ({err:.0}% err)\n",
            predicted_total * 1e3,
            measured_total * 1e3
        );
    }

    // ---- controlled calibration on the mock engine --------------------
    // The real-engine rows above sit in the compile-dominated regime
    // (predicted N* ≫ window). To validate the model *across* regimes,
    // drive the dispatcher with a mock engine whose C and E_i are exact,
    // and compare predicted vs measured crossovers directly.
    println!("== controlled calibration (mock engine, C=2ms, E=[0.4, 4, 2]ms) ==");
    {
        use jitune::coordinator::{Dispatcher, KernelRegistry};
        use jitune::runtime::mock::{MockEngine, MockSpec};
        use jitune::tensor::HostTensor;
        use std::time::Duration;

        let dir = std::env::temp_dir().join(format!("jitune-cmv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut entries = Vec::new();
        for i in 0..3 {
            let id = format!("kern.v{i}.n8");
            std::fs::write(dir.join(format!("{id}.hlo.txt")), "HloModule dummy\n").unwrap();
            entries.push(format!(
                r#"{{"id":"{id}","kernel":"kern","param":"p","value":{i},"label":"v{i}",
                    "size":8,"inputs":["f32[8,8]"],"output":"f32[8,8]","path":"{id}.hlo.txt","flops":1}}"#
            ));
        }
        let mock_manifest = jitune::manifest::Manifest::from_json_str(
            &format!(r#"{{"schema":1,"jax_version":"x","entries":[{}]}}"#, entries.join(",")),
            dir,
        )
        .unwrap();
        let exec_ms = [0.4f64, 4.0, 2.0];
        let mut spec = MockSpec::default().with_compile_cost(Duration::from_millis(2));
        for (i, &e) in exec_ms.iter().enumerate() {
            spec = spec.with_cost(&format!("kern.v{i}.n8"), Duration::from_secs_f64(e * 1e-3));
        }
        let mut d = Dispatcher::new(KernelRegistry::new(mock_manifest), Box::new(MockEngine::new(spec)));
        let inputs = [HostTensor::zeros(&[8, 8])];
        let window = 40usize;
        let mut cum = Vec::with_capacity(window);
        let mut acc = 0.0;
        for _ in 0..window {
            let out = d.call("kern", &inputs).expect("call");
            acc += out.total.as_secs_f64();
            cum.push(acc);
        }
        let model = CostModel::new(2e-3, exec_ms.iter().map(|e| e * 1e-3).collect());
        for p in 0..3 {
            let predicted = model.crossover(p);
            let measured = cum
                .iter()
                .enumerate()
                .position(|(n, &a)| a <= model.e_fixed(p, n + 1));
            let pred_s = predicted.map(|n| n.to_string()).unwrap_or_else(|| "never".into());
            let meas_s = measured.map(|i| (i + 1).to_string()).unwrap_or_else(|| format!(">{window}"));
            println!("  vs fixed:v{p} (E_p={}ms)  predicted N*={pred_s:<7} measured N*={meas_s}", exec_ms[p]);
            rows.push(vec![
                "mock".into(),
                format!("v{p}"),
                "0.002".into(),
                format!("{:.6}", exec_ms[p] * 1e-3),
                pred_s,
                meas_s,
            ]);
        }
        let predicted_total = model.e_auto(window);
        let measured_total = *cum.last().unwrap();
        println!(
            "  Eq.1 total @ {window} calls: predicted {:.1}ms, measured {:.1}ms ({:.0}% err)",
            predicted_total * 1e3,
            measured_total * 1e3,
            (predicted_total - measured_total).abs() / measured_total * 100.0
        );
    }

    let header = ["size", "baseline", "C_s", "Ep_s", "predicted_Nstar", "measured_Nstar"];
    jitune::report::write_figure_file("costmodel.csv", &chart::csv(&header, &rows)).expect("csv");
    println!("wrote target/figures/costmodel.csv");
}
