//! **Figure 2** — Per-iteration execution time, first 15 iterations,
//! three matrix sizes, log-scale y.
//!
//! The paper's benchmark chooses between three loop-order matmul
//! implementations (Listing 5). Iterations 0–2 are tuning iterations
//! (JIT compile + run each variant), iteration 3 compiles the final
//! winner, and the rest run the cached winner. Compile cost dominates
//! small sizes and becomes relatively negligible on larger ones.
//!
//! A **fused** series rides along: the same problem tuned through
//! `Dispatcher::call_batch` with 3 co-scheduled callers per leader
//! round — all tuning iterations land in round 0 (plus the in-round
//! finalize), so the compile spike collapses from iterations 0..3 into
//! a single round.
//!
//! Output: stdout chart (log y) + `target/figures/fig2.csv`.

use jitune::coordinator::CallRoute;
use jitune::report::bench::{
    artifacts_or_skip, autotuned_run, fresh_dispatcher, fused_autotuned_run,
};
use jitune::report::Figure;
use jitune::util::chart::Series;

const ITERS: usize = 15;
const SIZES: &[i64] = &[64, 128, 256];
const FUSED_WIDTH: usize = 3;
const FUSED_SIZE: i64 = 128;

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("fig2") else { return };

    println!(
        "== Fig 2: per-iteration time, matmul loop-order choice, first {ITERS} iterations ==\n"
    );
    let mut series = Vec::new();
    let mut rows = Vec::new();

    for &size in SIZES {
        let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
        let outcomes = autotuned_run(&mut d, "matmul_order", size, ITERS, 42).expect("run");
        let points: Vec<(f64, f64)> = outcomes
            .iter()
            .enumerate()
            .map(|(i, o)| (i as f64, o.total.as_secs_f64()))
            .collect();
        println!("n={size}:");
        for (i, o) in outcomes.iter().enumerate() {
            let phase = match o.route {
                CallRoute::Explored => "explore",
                CallRoute::Finalized => "finalize",
                CallRoute::Tuned => "tuned",
                CallRoute::Default => "default",
            };
            println!(
                "  iter {i:2} {phase:<9} {:<6} {:9.3}ms{}",
                o.variant_id.split('.').nth(1).unwrap_or("?"),
                o.total.as_secs_f64() * 1e3,
                if o.compiled { "  [JIT compile]" } else { "" }
            );
            rows.push(vec![
                size.to_string(),
                i.to_string(),
                format!("{:.6}", o.total.as_secs_f64()),
                phase.to_string(),
                o.variant_id.clone(),
            ]);
        }
        println!();
        series.push(Series::new(format!("n={size}"), points));
    }

    // Fused series: per-round leader time with 3 co-scheduled callers —
    // every tuning iteration fuses into round 0 and the winner finalizes
    // in-round, so round 1+ is already steady state.
    {
        let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
        let rounds = fused_autotuned_run(&mut d, "matmul_order", FUSED_SIZE, ITERS, FUSED_WIDTH, 42)
            .expect("fused run");
        println!("n={FUSED_SIZE} fused (width {FUSED_WIDTH}):");
        let mut points = Vec::new();
        for (r, (round_wall, outcomes)) in rounds.iter().enumerate() {
            let ok: Vec<_> = outcomes.iter().filter_map(|o| o.as_ref().ok()).collect();
            // Wall time of the whole round: includes the caller-less
            // in-round finalize compile, which no CallOutcome carries.
            let round_s: f64 = round_wall.as_secs_f64();
            let phase = match ok.first().map(|o| o.route) {
                Some(CallRoute::Explored) => "explore",
                Some(CallRoute::Finalized) => "finalize",
                _ => "tuned",
            };
            println!("  round {r:2} {phase:<9} {:9.3}ms ({} calls)", round_s * 1e3, ok.len());
            points.push((r as f64, round_s.max(1e-9)));
            rows.push(vec![
                FUSED_SIZE.to_string(),
                r.to_string(),
                format!("{round_s:.6}"),
                format!("fused-{phase}"),
                ok.first().map(|o| o.variant_id.clone()).unwrap_or_default(),
            ]);
        }
        println!();
        series.push(Series::new(
            format!("n={FUSED_SIZE} fused w{FUSED_WIDTH}"),
            points,
        ));
    }

    let fig = Figure {
        stem: "fig2".into(),
        title: "Fig 2: iteration time (s), log y — compile spikes on iters 0..3".into(),
        header: vec![
            "size".into(),
            "iteration".into(),
            "seconds".into(),
            "phase".into(),
            "variant".into(),
        ],
        rows,
        series,
        log_y: true,
    };
    let rendered = fig.emit().expect("emit");
    println!("{rendered}");
    println!("wrote target/figures/fig2.csv and fig2.txt");
}
