//! **Figure 1** — Block-size choice for different matrix sizes.
//!
//! The paper runs the tiled matmul (Listing 6) repeatedly and histograms
//! which block size the tuner picks per matrix size: 64 for medium
//! matrices (128, 256), 512 for large (≥512), noisy for small ones where
//! tiling barely matters. This bench repeats the whole tuning process R
//! times per size with fresh tuner state and reports the choice counts.
//!
//! Output: stdout table + bars, `target/figures/fig1.csv`.

use std::collections::BTreeMap;

use jitune::report::bench::{artifacts_or_skip, autotuned_run, fresh_dispatcher, repeats};
use jitune::util::chart;

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("fig1") else { return };
    let repeats = repeats(5);
    let sizes = manifest.sizes("matmul_tiled");
    let blocks: Vec<i64> = manifest
        .problem("matmul_tiled", sizes[0])
        .unwrap()
        .variants
        .iter()
        .map(|v| v.value)
        .collect();

    println!("== Fig 1: block-size choice per matrix size ({repeats} tuning runs each) ==\n");
    let mut rows = Vec::new();
    let mut counts_by_size: BTreeMap<i64, BTreeMap<i64, usize>> = BTreeMap::new();

    for &size in &sizes {
        for rep in 0..repeats {
            let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
            // run until tuned: k explores + 1 finalize (+1 safety)
            let iters = blocks.len() + 2;
            let outcomes =
                autotuned_run(&mut d, "matmul_tiled", size, iters, 42 + rep as u64).expect("run");
            let chosen = outcomes.last().unwrap().value;
            *counts_by_size.entry(size).or_default().entry(chosen).or_default() += 1;
        }
    }

    // paper-style table: one row per size, counts per block candidate
    print!("{:>6} |", "size");
    for b in &blocks {
        print!("{b:>6}");
    }
    println!();
    println!("{}", "-".repeat(8 + 6 * blocks.len()));
    for (&size, counts) in &counts_by_size {
        print!("{size:>6} |");
        for b in &blocks {
            let c = counts.get(b).copied().unwrap_or(0);
            print!("{c:>6}");
        }
        println!();
        for b in &blocks {
            rows.push(vec![
                size.to_string(),
                b.to_string(),
                counts.get(b).copied().unwrap_or(0).to_string(),
            ]);
        }
    }

    // bar chart per size
    println!();
    for (&size, counts) in &counts_by_size {
        let bars: Vec<(String, f64)> = blocks
            .iter()
            .map(|b| (format!("b{b}"), counts.get(b).copied().unwrap_or(0) as f64))
            .collect();
        print!("{}", chart::bars(&format!("n={size}"), &bars, 30));
    }

    let header = ["size", "block", "count"];
    jitune::report::write_figure_file("fig1.csv", &chart::csv(&header, &rows)).expect("csv");
    println!("wrote target/figures/fig1.csv\n");

    // paper-shape sanity notes
    for (&size, counts) in &counts_by_size {
        let (&best_block, &n) = counts.iter().max_by_key(|(_, &c)| c).unwrap();
        let stable = n == repeats;
        println!(
            "n={size}: modal choice b{best_block} ({n}/{repeats} runs{})",
            if stable { ", stable" } else { "" }
        );
    }
}
