//! **Ablation: AOT-all-variants vs JIT autotuning** — the alternative
//! the paper's introduction discusses ("generate all the variants at
//! compile-time, and only run and select the best one at run-time") and
//! rejects in favor of JIT.
//!
//! Compares, on the loop-order matmul:
//! * `jit-autotune` — the paper's approach (compiles lazily during the
//!   first calls; losers evicted).
//! * `aot-all` — compile *every* variant up front, then select by
//!   measurement (no compile on the request path, but full upfront cost
//!   and k resident executables).
//! * `oracle` — perfect pick, setup = one measurement pass.
//!
//! Reported: time-to-first-result, setup cost, cumulative time at the
//! window end, resident executables.
//!
//! Output: stdout table + `target/figures/ablation_aot.csv`.

use jitune::baseline::{AotAll, Oracle};
use jitune::report::bench::{artifacts_or_skip, autotuned_run, cumulative, fresh_dispatcher};
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::util::chart;
use jitune::workload::inputs_for;

const SIZE: i64 = 256;
const ITERS: usize = 40;

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("ablation_aot") else { return };
    let problem = manifest.problem("matmul_order", SIZE).expect("problem").clone();
    let inputs = inputs_for(&problem, 42);

    println!("== Ablation: JIT autotune vs AOT-all-variants (matmul_order n={SIZE}, {ITERS} calls) ==\n");
    let mut rows = Vec::new();

    // jit-autotune
    let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
    let outcomes = autotuned_run(&mut d, "matmul_order", SIZE, ITERS, 42).expect("run");
    let cum = cumulative(&outcomes);
    let first = outcomes[0].total.as_secs_f64();
    println!(
        "jit-autotune : first-result {:7.1}ms  setup {:>9} cumulative {:8.1}ms  resident exes: 1",
        first * 1e3,
        "(none)",
        cum.last().unwrap() * 1e3
    );
    rows.push(vec![
        "jit-autotune".into(),
        format!("{first:.6}"),
        "0".into(),
        format!("{:.6}", cum.last().unwrap()),
        "1".into(),
    ]);

    // aot-all
    let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
    let run = AotAll::run(&manifest, &mut cache, &problem, &inputs, ITERS).expect("aot");
    let aot_first = run.setup.as_secs_f64() + run.per_call[0].as_secs_f64();
    println!(
        "aot-all      : first-result {:7.1}ms  setup {:7.1}ms cumulative {:8.1}ms  resident exes: {}",
        aot_first * 1e3,
        run.setup.as_secs_f64() * 1e3,
        (run.setup.as_secs_f64() + run.total()) * 1e3,
        cache.resident()
    );
    rows.push(vec![
        "aot-all".into(),
        format!("{aot_first:.6}"),
        format!("{:.6}", run.setup.as_secs_f64()),
        format!("{:.6}", run.setup.as_secs_f64() + run.total()),
        cache.resident().to_string(),
    ]);

    // oracle
    let mut cache2 = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
    let orun = Oracle::run(&manifest, &mut cache2, &problem, &inputs, ITERS).expect("oracle");
    println!(
        "oracle       : first-result {:7.1}ms  setup {:7.1}ms cumulative {:8.1}ms  resident exes: {}",
        (orun.setup.as_secs_f64() + orun.per_call[0].as_secs_f64()) * 1e3,
        orun.setup.as_secs_f64() * 1e3,
        (orun.setup.as_secs_f64() + orun.total()) * 1e3,
        cache2.resident()
    );
    rows.push(vec![
        "oracle".into(),
        format!("{:.6}", orun.setup.as_secs_f64() + orun.per_call[0].as_secs_f64()),
        format!("{:.6}", orun.setup.as_secs_f64()),
        format!("{:.6}", orun.setup.as_secs_f64() + orun.total()),
        cache2.resident().to_string(),
    ]);

    println!(
        "\njit-autotune produces its first (tuning) result while aot-all is still compiling \
         the full variant set; aot-all keeps every executable resident. Same asymptotic \
         slope; the trade is startup latency + memory vs total tuning overhead."
    );

    let header = ["policy", "first_result_s", "setup_s", "cumulative_s", "resident"];
    jitune::report::write_figure_file("ablation_aot.csv", &chart::csv(&header, &rows))
        .expect("csv");
    println!("wrote target/figures/ablation_aot.csv");
}
