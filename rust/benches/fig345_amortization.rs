//! **Figures 3, 4, 5** — Cumulative execution time: autotuned vs the
//! three fixed loop-order implementations, at small / medium / large
//! matrix sizes.
//!
//! Paper findings to reproduce in shape:
//! * **Fig 3 (small, N=128 → ours n=64)**: the JIT compile cost is
//!   prohibitive relative to per-call time; the autotuned curve keeps a
//!   constant offset above the fixed ones within 100 calls (crossover
//!   far beyond the window).
//! * **Fig 4 (medium, N=512 → ours n=256)**: the autotuned curve
//!   parallels the best fixed one, shifted up by the tuning overhead.
//! * **Fig 5 (large, N=2048 → ours n=512)**: per-call gain dominates;
//!   the autotuned curve crosses suboptimal fixed ones after a few
//!   calls.
//!
//! Output: stdout charts + `target/figures/fig{3,4,5}.csv`.

use jitune::baseline::FixedVariant;
use jitune::report::bench::{artifacts_or_skip, autotuned_run, cumulative, fresh_dispatcher, steady_start};
use jitune::report::Figure;
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::util::chart::Series;
use jitune::workload::inputs_for;

/// (figure id, kernel, matrix size, call count, baseline variant indexes
/// or empty = all). Sizes are scaled from the paper's 128/512/2048 to
/// the CPU-PJRT interpret-mode substrate; the compile-vs-exec regimes
/// match (see DESIGN.md §Substitutions).
///
/// `fig5s` is a substrate-honest supplement: XLA largely equalizes the
/// three loop orders at steady state (the JIT compiler itself removes
/// the paper's loop-order spread), so the paper's Fig-5 crossover-vs-
/// suboptimal-choice claim is additionally demonstrated on the
/// block-size axis, where wrong fixed choices (b8) remain genuinely
/// slow.
const CASES: &[(&str, &str, i64, usize, &[usize])] = &[
    ("fig3", "matmul_order", 64, 100, &[]),
    ("fig4", "matmul_order", 256, 60, &[]),
    ("fig5", "matmul_order", 512, 12, &[]),
    // baselines b32/b64/b256 (b8 at n=512 = 262k interpret-mode grid
    // steps — minutes per call, excluded from the fixed baselines; the
    // autotuned sweep still measures it once)
    ("fig5s", "matmul_tiled", 512, 12, &[2, 3, 5]),
];

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("fig345") else { return };

    for &(fig_id, kernel, size, iters, baseline_idx) in CASES {
        println!("\n== {fig_id}: cumulative time, {kernel}, n={size}, {iters} calls ==");
        let problem = manifest.problem(kernel, size).expect("problem").clone();
        let inputs = inputs_for(&problem, 42);

        // autotuned run (paper's exhaustive sweep)
        let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
        let outcomes = autotuned_run(&mut d, kernel, size, iters, 42).expect("run");
        let auto_cum = cumulative(&outcomes);
        let winner = outcomes.last().unwrap().variant_id.clone();

        // fig5s also demonstrates §3.3 condition (b): the sweep's single
        // exploration of the pathological b8 variant dwarfs everything.
        // The §5 hill-climb heuristic starts mid-array and never touches
        // it — run it alongside for the comparison.
        let hillclimb_cum = if fig_id == "fig5s" {
            let tuner = jitune::autotuner::Autotuner::with_factory(Box::new(|_values| {
                Box::new(jitune::autotuner::HillClimb::new())
            }));
            let mut dh =
                jitune::report::bench::fresh_dispatcher_with(&manifest, tuner).expect("dispatcher");
            let outcomes_h = autotuned_run(&mut dh, kernel, size, iters, 42).expect("run");
            println!(
                "  autotuned(hillclimb): total={:9.1}ms (winner {})",
                cumulative(&outcomes_h).last().unwrap() * 1e3,
                outcomes_h.last().unwrap().variant_id
            );
            Some(cumulative(&outcomes_h))
        } else {
            None
        };

        // fixed baselines
        let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
        let mut baselines = Vec::new();
        let indexes: Vec<usize> = if baseline_idx.is_empty() {
            (0..problem.variants.len()).collect()
        } else {
            baseline_idx.to_vec()
        };
        for idx in indexes {
            let run = FixedVariant::run(&manifest, &mut cache, &problem, idx, &inputs, iters)
                .expect("baseline");
            baselines.push(run);
        }

        // table: every curve's total + crossover analysis
        println!("  autotuned: total={:9.1}ms  (winner {winner}, steady from call {:?})",
            auto_cum.last().unwrap() * 1e3, steady_start(&outcomes));
        let mut rows = Vec::new();
        let mut series =
            vec![Series::new("autotuned", auto_cum.iter().enumerate().map(|(i, &c)| (i as f64, c)).collect::<Vec<_>>())];
        if let Some(h) = &hillclimb_cum {
            series.push(Series::new(
                "autotuned(hillclimb)",
                h.iter().enumerate().map(|(i, &c)| (i as f64, c)).collect(),
            ));
        }
        for b in &baselines {
            let cum = b.cumulative();
            let crossover = auto_cum
                .iter()
                .zip(&cum)
                .position(|(a, f)| a <= f)
                .map(|i| i.to_string())
                .unwrap_or_else(|| format!(">{iters}"));
            println!(
                "  {:<10} total={:9.1}ms  autotuned crosses at call {crossover}",
                b.label,
                b.total() * 1e3
            );
            series.push(Series::new(
                b.label.clone(),
                cum.iter().enumerate().map(|(i, &c)| (i as f64, c)).collect(),
            ));
        }
        for (i, &a) in auto_cum.iter().enumerate() {
            let mut row = vec![i.to_string(), format!("{a:.6}")];
            for b in &baselines {
                row.push(format!("{:.6}", b.cumulative()[i]));
            }
            rows.push(row);
        }

        let mut header = vec!["call".to_string(), "autotuned".to_string()];
        header.extend(baselines.iter().map(|b| b.label.clone()));
        let fig = Figure {
            stem: fig_id.to_string(),
            title: format!("{fig_id}: cumulative seconds, {kernel} n={size}"),
            header,
            rows,
            series,
            log_y: false,
        };
        let rendered = fig.emit().expect("emit");
        println!("{rendered}");
    }
    println!("wrote target/figures/fig{{3,4,5,5s}}.csv (+ .txt charts)");
}
