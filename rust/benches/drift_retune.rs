//! **Drift retune** — detection + recovery timeline of the automatic
//! drift policy on a synthetic drifting workload.
//!
//! Worker threads hammer a tuned kernel on the fast lane; mid-run the
//! winning variant's latency is degraded 3x (the mock's `LatencyFault`).
//! The drift policy must notice the windowed regression, retune, and
//! converge to the variant that is now fastest. The bench reports the
//! per-slice mean latency timeline (healthy → degraded → recovered) and
//! the detection latency: time from injection until the new winner
//! serves.
//!
//! Output: stdout chart + `target/figures/drift_retune.csv` + a
//! machine-readable JSON report `target/figures/drift_retune.json`.
//!
//! Env knobs: `JITUNE_BENCH_DRIFT_THREADS` (default 4),
//! `JITUNE_BENCH_DRIFT_PHASE_MS` (healthy/recovered phase length,
//! default 1000).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, DriftPolicy, KernelRegistry, ServerOptions,
};
use jitune::report::Figure;
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;
use jitune::util::chart::Series;
use jitune::util::json::{n, s, Value};

const SLICE_MS: f64 = 100.0;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    jitune::util::logging::init();
    let threads = env_usize("JITUNE_BENCH_DRIFT_THREADS", 4);
    let phase_ms = env_usize("JITUNE_BENCH_DRIFT_PHASE_MS", 1000) as u64;
    println!(
        "== drift retune: detection + recovery timeline ({threads} thread(s), \
         {phase_ms}ms phases) =="
    );

    // v1 (250us) wins tuning; a 3x shift (750us) makes v0 (500us) the
    // rightful winner of the rematch.
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(500))
        .with_cost("kern.v1.n8", Duration::from_micros(250))
        .with_sleep_exec();
    let fault = spec.latency_fault.clone();
    let policy = DriftPolicy {
        window: Duration::from_millis(100),
        min_samples: 20,
        ratio_threshold: 2.0,
        cooldown: Duration::from_millis(300),
        consecutive_windows: 2,
        ..DriftPolicy::default()
    };
    let coord = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { drift: Some(policy), ..ServerOptions::default() },
    )
    .expect("spawn coordinator");

    // tune to steady state
    let h = coord.handle();
    loop {
        if h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("warm call").route
            == CallRoute::Tuned
        {
            break;
        }
    }

    // timeline: workers record (t, latency, served value) until stopped
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..threads {
        let h = coord.handle();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut samples: Vec<(f64, f64, i64)> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                let c0 = Instant::now();
                let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call");
                samples.push((
                    t0.elapsed().as_secs_f64(),
                    c0.elapsed().as_secs_f64(),
                    o.value,
                ));
            }
            samples
        }));
    }

    std::thread::sleep(Duration::from_millis(phase_ms));
    let inject_at = t0.elapsed().as_secs_f64();
    fault.set_scale("kern.v1.n8", 3.0);
    println!("  injected 3x shift at t={inject_at:.2}s");

    // wait for the policy to retune and the rematch to flip the winner
    let detect_deadline = Instant::now() + Duration::from_secs(60);
    let mut new_winner_at = None;
    while new_winner_at.is_none() && Instant::now() < detect_deadline {
        if h.tuned_value("kern", 8).expect("tuned_value") == Some(0) {
            new_winner_at = Some(t0.elapsed().as_secs_f64());
        } else {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    match new_winner_at {
        Some(at) => println!(
            "  new winner serving at t={at:.2}s (detection+rematch: {:.0}ms)",
            (at - inject_at) * 1e3
        ),
        None => println!("  WARNING: no automatic retune observed within 60s"),
    }

    std::thread::sleep(Duration::from_millis(phase_ms));
    stop.store(true, Ordering::Relaxed);
    let mut samples: Vec<(f64, f64, i64)> = Vec::new();
    for j in joins {
        samples.extend(j.join().expect("worker"));
    }
    samples.sort_by(|a, b| a.0.total_cmp(&b.0));

    // slice the timeline into SLICE_MS buckets of mean latency
    let end = samples.last().map(|x| x.0).unwrap_or(0.0);
    let slices = (end * 1e3 / SLICE_MS).ceil() as usize + 1;
    let mut sums = vec![0.0f64; slices];
    let mut counts = vec![0u64; slices];
    for &(t, lat, _) in &samples {
        let idx = ((t * 1e3 / SLICE_MS) as usize).min(slices - 1);
        sums[idx] += lat;
        counts[idx] += 1;
    }
    let mut rows = Vec::new();
    let mut points = Vec::new();
    for i in 0..slices {
        if counts[i] == 0 {
            continue;
        }
        let t_s = i as f64 * SLICE_MS / 1e3;
        let mean_ms = sums[i] / counts[i] as f64 * 1e3;
        rows.push(vec![format!("{t_s:.1}"), format!("{mean_ms:.3}"), counts[i].to_string()]);
        points.push((t_s, mean_ms));
    }

    let fig = Figure {
        stem: "drift_retune".into(),
        title: "mean call latency timeline across a 3x drift + automatic retune".into(),
        header: vec!["t_s".into(), "mean_latency_ms".into(), "calls".into()],
        rows,
        series: vec![Series::new("mean_latency_ms", points)],
        log_y: false,
    };
    let rendered = fig.emit().expect("emit");
    println!("{rendered}");

    let json = h.stats_json().expect("stats_json");
    let drift_retunes = json
        .get("kernels")
        .and_then(|k| k.get("kern"))
        .and_then(|k| k.get("drift_retunes"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0);
    let report = Value::Obj(vec![
        ("bench".into(), s("drift_retune")),
        ("engine".into(), s("mock(sleep)")),
        ("threads".into(), n(threads as f64)),
        ("phase_ms".into(), n(phase_ms as f64)),
        ("inject_at_s".into(), n(inject_at)),
        (
            "new_winner_at_s".into(),
            new_winner_at.map(n).unwrap_or(Value::Null),
        ),
        (
            "detection_ms".into(),
            new_winner_at.map(|at| n((at - inject_at) * 1e3)).unwrap_or(Value::Null),
        ),
        ("drift_retunes".into(), n(drift_retunes)),
        ("total_calls".into(), n(samples.len() as f64)),
    ]);
    jitune::report::write_figure_file("drift_retune.json", &report.to_json_pretty())
        .expect("json");
    println!("wrote target/figures/drift_retune.{{csv,txt,json}}");
}
