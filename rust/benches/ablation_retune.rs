//! **Ablation: re-tuning on argument change** — paper §3.2 "Handling
//! calls with different arguments": a call with a different argument
//! signature is a different autotuning problem and restarts tuning.
//!
//! A trace calls matmul_tiled at n=128 for 20 calls, then switches to
//! n=256. The bench verifies (a) the switch triggers a fresh tuning
//! phase (explore routes reappear), (b) the first problem's tuned state
//! is untouched and still serves cached calls afterwards, and (c) each
//! problem settles on its own winner.
//!
//! Output: stdout timeline + `target/figures/ablation_retune.csv`.

use jitune::coordinator::CallRoute;
use jitune::report::bench::{artifacts_or_skip, fresh_dispatcher};
use jitune::util::chart;
use jitune::workload::{inputs_for, CallTrace};

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("ablation_retune") else { return };
    let mut d = fresh_dispatcher(&manifest).expect("dispatcher");

    let trace = CallTrace::with_size_switch("matmul_tiled", 128, 256, 20, 40);
    // tail: return to the first size — must be served from cache, no re-tuning
    let mut calls = trace.calls.clone();
    calls.extend(CallTrace::uniform("matmul_tiled", 128, 5).calls);

    println!("== Ablation: re-tuning on shape change (n=128 ->[call 20] n=256 ->[call 40] n=128) ==\n");
    let mut rows = Vec::new();
    let mut retune_started = None;
    for (i, call) in calls.iter().enumerate() {
        let problem = d.registry().problem(&call.kernel, call.size).expect("problem").clone();
        let inputs = inputs_for(&problem, 42);
        let out = d.call(&call.kernel, &inputs).expect("call");
        let route = match out.route {
            CallRoute::Explored => "explore",
            CallRoute::Finalized => "finalize",
            CallRoute::Tuned => "tuned",
            CallRoute::Default => "default",
        };
        if i >= 20 && retune_started.is_none() && out.route == CallRoute::Explored {
            retune_started = Some(i);
        }
        if i < 9 || (19..29).contains(&i) || i >= 39 {
            println!(
                "call {i:2} n={:<4} {route:<9} block={:<4} {:8.2}ms{}",
                call.size,
                out.value,
                out.total.as_secs_f64() * 1e3,
                if out.compiled { " [compile]" } else { "" }
            );
        } else if i == 9 || i == 29 {
            println!("   ...");
        }
        rows.push(vec![
            i.to_string(),
            call.size.to_string(),
            route.to_string(),
            out.value.to_string(),
            format!("{:.6}", out.total.as_secs_f64()),
        ]);
    }

    // assertions on the paper-mandated behaviour
    assert_eq!(retune_started, Some(20), "size switch must start a fresh tuning phase");
    let tuned_128 = d.tuned_value("matmul_tiled", 128);
    let tuned_256 = d.tuned_value("matmul_tiled", 256);
    assert!(tuned_128.is_some() && tuned_256.is_some());
    println!("\nindependent winners: n=128 -> block {tuned_128:?}, n=256 -> block {tuned_256:?}");
    println!("return to n=128 at call 40 was served tuned (no re-tuning) ✓");

    let header = ["call", "size", "route", "block", "seconds"];
    jitune::report::write_figure_file("ablation_retune.csv", &chart::csv(&header, &rows))
        .expect("csv");
    println!("wrote target/figures/ablation_retune.csv");
}
