//! §Perf probe — paired micro-measurements of the L3 hot-path changes,
//! so before/after deltas are measured in one process on one machine
//! state (immune to background load differences between runs).
//!
//! Probes:
//!  1. Literal construction: `vec1 + reshape` (baseline) vs
//!     `create_from_shape_and_untyped_data` (optimized single copy).
//!  2. Call-plan resolution: `problem_for_inputs().clone()` per call
//!     (baseline) vs the signature-string cached plan (first pass) vs
//!     the allocation-free hashed CallPlan lookup the dispatcher now
//!     uses (`fastlane::plan_hash`).
//!  3. End-to-end steady-state call vs raw executable dispatch — the
//!     residual coordinator overhead.
//!
//! Output: stdout + `target/figures/perf_probe.csv`.

use std::time::Instant;

use jitune::coordinator::{fastlane, CallRoute, KernelRegistry};
use jitune::report::bench::{artifacts_or_skip, fresh_dispatcher};
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::tensor::HostTensor;
use jitune::util::chart;
use jitune::util::stats::Summary;

fn time_n(n: usize, mut f: impl FnMut()) -> Summary {
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

fn main() {
    jitune::util::logging::init();
    let Some(manifest) = artifacts_or_skip("perf_probe") else { return };
    let mut rows = Vec::new();
    println!("== §Perf probe (paired in-process measurements) ==\n");

    // ---- probe 1: literal construction --------------------------------
    for shape in [vec![64usize, 64], vec![256, 512]] {
        let t = HostTensor::random(&shape, 1);
        let dims_i64: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let n = 2000;
        let old = time_n(n, || {
            let lit = xla::Literal::vec1(t.data()).reshape(&dims_i64).unwrap();
            std::hint::black_box(&lit);
        });
        let new = time_n(n, || {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &shape,
                bytes,
            )
            .unwrap();
            std::hint::black_box(&lit);
        });
        let speedup = old.mean / new.mean;
        println!(
            "literal f32{shape:?}: vec1+reshape {:.1}µs -> single-copy {:.1}µs  ({speedup:.2}x)",
            old.mean * 1e6,
            new.mean * 1e6
        );
        rows.push(vec![
            format!("literal_{}", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")),
            format!("{:.9}", old.mean),
            format!("{:.9}", new.mean),
            format!("{speedup:.3}"),
        ]);
    }

    // ---- probe 2: per-call plan resolution -----------------------------
    {
        let registry = KernelRegistry::new(manifest.clone());
        let inputs = [HostTensor::random(&[64, 64], 1), HostTensor::random(&[64, 64], 2)];
        let n = 20_000;
        let old = time_n(n, || {
            // what the dispatcher originally did every call
            let p = registry.problem_for_inputs("matmul_tiled", &inputs).unwrap().clone();
            std::hint::black_box(&p);
        });
        // the signature-string cached-plan path (first §Perf pass): a
        // string join + (String, String) key allocation on every hit
        let mut plans = std::collections::HashMap::new();
        plans.insert(
            (
                "matmul_tiled".to_string(),
                inputs.iter().map(HostTensor::signature).collect::<Vec<_>>().join(","),
            ),
            42usize,
        );
        let strings = time_n(n, || {
            let sig = inputs.iter().map(HostTensor::signature).collect::<Vec<_>>().join(",");
            let v = plans.get(&("matmul_tiled".to_string(), sig)).unwrap();
            std::hint::black_box(v);
        });
        // the hashed-plan path the dispatcher uses now: zero allocations
        // on the hit (jitune::coordinator::fastlane::plan_hash)
        let mut hashed = std::collections::HashMap::new();
        hashed.insert(fastlane::plan_hash("matmul_tiled", &inputs), 42usize);
        let new = time_n(n, || {
            let h = fastlane::plan_hash("matmul_tiled", &inputs);
            let v = hashed.get(&h).unwrap();
            std::hint::black_box(v);
        });
        let speedup = old.mean / new.mean;
        println!(
            "plan resolve: problem.clone() {:.2}µs -> sig strings {:.2}µs -> hashed plan \
             {:.2}µs  ({speedup:.2}x vs clone, {:.2}x vs strings)",
            old.mean * 1e6,
            strings.mean * 1e6,
            new.mean * 1e6,
            strings.mean / new.mean
        );
        rows.push(vec![
            "plan_resolution".into(),
            format!("{:.9}", old.mean),
            format!("{:.9}", new.mean),
            format!("{speedup:.3}"),
        ]);
        rows.push(vec![
            "plan_resolution_vs_strings".into(),
            format!("{:.9}", strings.mean),
            format!("{:.9}", new.mean),
            format!("{:.3}", strings.mean / new.mean),
        ]);
    }

    // ---- probe 3: dispatcher overhead over raw execution ----------------
    {
        let mut d = fresh_dispatcher(&manifest).expect("dispatcher");
        let inputs = [HostTensor::random(&[64, 64], 1), HostTensor::random(&[64, 64], 2)];
        // tune to steady state
        loop {
            if d.call("matmul_tiled", &inputs).unwrap().route == CallRoute::Finalized {
                break;
            }
        }
        let n = 300;
        let full = time_n(n, || {
            let out = d.call("matmul_tiled", &inputs).unwrap();
            std::hint::black_box(&out);
        });
        // raw: same variant, executed straight off a compile cache
        let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
        let winner_value = d.tuned_value("matmul_tiled", 64).unwrap();
        let problem = manifest.problem("matmul_tiled", 64).unwrap();
        let variant =
            problem.variants.iter().find(|v| v.value == winner_value).unwrap().clone();
        cache.get_or_compile(&manifest, &variant).unwrap();
        let raw = time_n(n, || {
            let (exe, _) = cache.get_or_compile(&manifest, &variant).unwrap();
            let out = exe.execute(&inputs).unwrap();
            std::hint::black_box(&out);
        });
        let overhead_us = (full.median - raw.median) * 1e6;
        println!(
            "steady call: dispatcher p50 {:.1}µs vs raw p50 {:.1}µs -> coordinator overhead ≈ {overhead_us:.1}µs/call",
            full.median * 1e6,
            raw.median * 1e6
        );
        rows.push(vec![
            "dispatch_overhead".into(),
            format!("{:.9}", full.median),
            format!("{:.9}", raw.median),
            format!("{overhead_us:.3}"),
        ]);
    }

    let header = ["probe", "baseline_s", "optimized_s", "speedup_or_us"];
    jitune::report::write_figure_file("perf_probe.csv", &chart::csv(&header, &rows))
        .expect("csv");
    println!("\nwrote target/figures/perf_probe.csv");
}
