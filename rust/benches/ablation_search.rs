//! **Ablation: search strategies** — the paper's §5 future work.
//!
//! Compares the paper's exhaustive sweep against random search, hill
//! climbing and simulated annealing on (a) synthetic cost surfaces with
//! known optima (many seeds, mock timing) and (b) one real tuning
//! problem (matmul_tiled block size on PJRT).
//!
//! Metrics: tuning iterations used, probability of finding the global
//! optimum, and *regret* (chosen cost − optimal cost) / optimal.
//!
//! Output: stdout table + `target/figures/ablation_search.csv`.

use jitune::autotuner::search::{self, SearchStrategy};
use jitune::autotuner::{Autotuner, History};
use jitune::report::bench::{artifacts_or_skip, autotuned_run, fresh_dispatcher_with};
use jitune::util::chart;
use jitune::util::prng::Rng;

const STRATEGIES: &[&str] = &["sweep", "random:8", "hillclimb", "anneal:10"];

/// Synthetic surfaces over 12 candidates.
fn surfaces() -> Vec<(&'static str, Box<dyn Fn(usize, &mut Rng) -> f64>)> {
    vec![
        ("unimodal", Box::new(|i, rng| ((i as f64) - 8.0).powi(2) + 1.0 + rng.f64() * 0.05)),
        ("monotone", Box::new(|i, rng| 12.0 - i as f64 + rng.f64() * 0.05)),
        (
            "bimodal",
            Box::new(|i, rng| {
                let a = ((i as f64) - 2.0).powi(2) + 2.0;
                let b = ((i as f64) - 9.0).powi(2) + 1.0;
                a.min(b) + rng.f64() * 0.05
            }),
        ),
        ("noisy-flat", Box::new(|i, rng| 5.0 + if i == 6 { -1.0 } else { 0.0 } + rng.f64() * 0.2)),
    ]
}

fn run_strategy(spec: &str, surface: &dyn Fn(usize, &mut Rng) -> f64, seed: u64) -> (usize, f64) {
    let n = 12usize;
    let values: Vec<i64> = (0..n as i64).collect();
    let mut strategy: Box<dyn SearchStrategy> = search::from_spec(spec, n, seed).unwrap();
    let mut history = History::new(&values);
    let mut rng = Rng::seed(seed ^ 0xABCD);
    let mut iters = 0;
    while let Some(idx) = strategy.next(&history) {
        history.record(idx, surface(idx, &mut rng));
        iters += 1;
        if iters > 200 {
            break;
        }
    }
    // true optimum = argmin of the noise-free surface
    let mut noiseless = Rng::seed(0);
    let optimal = (0..n)
        .map(|i| surface(i, &mut noiseless))
        .fold(f64::INFINITY, f64::min);
    let chosen_idx = history.best_index().unwrap();
    let mut noiseless2 = Rng::seed(0);
    let chosen_cost = surface(chosen_idx, &mut noiseless2);
    let regret = (chosen_cost - optimal) / optimal;
    (iters, regret.max(0.0))
}

fn main() {
    jitune::util::logging::init();
    println!("== Ablation: search strategies (12 candidates, 30 seeds per surface) ==\n");
    let mut rows = Vec::new();

    println!(
        "{:<12} {:<12} {:>8} {:>12} {:>10}",
        "surface", "strategy", "iters", "mean regret", "hit rate"
    );
    for (name, surface) in surfaces() {
        for &spec in STRATEGIES {
            let mut total_iters = 0usize;
            let mut total_regret = 0.0;
            let mut hits = 0usize;
            let seeds = 30u64;
            for seed in 0..seeds {
                let (iters, regret) = run_strategy(spec, surface.as_ref(), seed);
                total_iters += iters;
                total_regret += regret;
                if regret < 0.05 {
                    hits += 1;
                }
            }
            let mean_iters = total_iters as f64 / seeds as f64;
            let mean_regret = total_regret / seeds as f64;
            let hit_rate = hits as f64 / seeds as f64;
            println!(
                "{name:<12} {spec:<12} {mean_iters:>8.1} {mean_regret:>11.1}% {hit_rate:>9.0}%",
                mean_regret = mean_regret * 100.0,
                hit_rate = hit_rate * 100.0
            );
            rows.push(vec![
                name.to_string(),
                spec.to_string(),
                format!("{mean_iters:.2}"),
                format!("{mean_regret:.4}"),
                format!("{hit_rate:.2}"),
            ]);
        }
        println!();
    }

    // real tuning problem: matmul_tiled block size at n=256
    if let Some(manifest) = artifacts_or_skip("ablation_search(real)") {
        println!("real problem: matmul_tiled n=256 (6 candidates) — iterations to tuned + winner");
        for &spec in STRATEGIES {
            let spec_owned = spec.to_string();
            let tuner = Autotuner::with_factory(Box::new(move |values| {
                search::from_spec(&spec_owned, values.len(), 42).unwrap()
            }));
            let mut d = fresh_dispatcher_with(&manifest, tuner).expect("dispatcher");
            let outcomes = autotuned_run(&mut d, "matmul_tiled", 256, 20, 42).expect("run");
            let explores =
                outcomes.iter().filter(|o| o.route == jitune::coordinator::CallRoute::Explored).count();
            let winner = d.tuned_value("matmul_tiled", 256);
            println!("  {spec:<12} tuning iterations={explores:<3} tuned block={winner:?}");
            rows.push(vec![
                "real:matmul_tiled".to_string(),
                spec.to_string(),
                explores.to_string(),
                format!("{winner:?}"),
                String::new(),
            ]);
        }
    }

    let header = ["surface", "strategy", "iters", "regret_or_winner", "hit_rate"];
    jitune::report::write_figure_file("ablation_search.csv", &chart::csv(&header, &rows))
        .expect("csv");
    println!("\nwrote target/figures/ablation_search.csv");
}
