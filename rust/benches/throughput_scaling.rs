//! **Throughput scaling** — steady-state tuned-call throughput at
//! 1/2/4/8 application threads, across the coordinator's three lanes:
//! single-lane baseline (every call through the leader channel), the
//! published-winner fast lane (tuned calls execute on the caller's
//! thread), and the worker pool (kernels refuse `shared()` — the PJRT
//! shape — so tuned calls route to N thread-pinned worker engines; the
//! pool runs with as many workers as application threads).
//!
//! Runs on the mock engine with sleep-based execution, modelling a kernel
//! offloaded to an accelerator: the host CPU is free during execution, so
//! the measurement isolates the *coordination* bottleneck rather than
//! host core count. The single lane serializes every call behind one
//! leader (throughput flat as threads grow); the fast lane scales with
//! the callers; the pool scales with its workers even though no
//! executable ever crosses a thread.
//!
//! Output: stdout chart + `target/figures/throughput_scaling.csv` (same
//! Figure pipeline as the fig* benches) + a machine-readable JSON report
//! `target/figures/throughput_scaling.json` including the headline
//! `pool_scaling_1_to_4` ratio (the ROADMAP claim, measured).
//!
//! Env knobs: `JITUNE_BENCH_CALLS` (calls per thread, default 300),
//! `JITUNE_BENCH_EXEC_US` (per-call execution sleep, default 200).

use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, Dispatcher, KernelRegistry, ServerOptions,
};
use jitune::report::Figure;
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::{spawn_pooled_mock, synthetic_manifest};
use jitune::util::chart::Series;
use jitune::util::json::{n, s, Value};

const THREADS: &[usize] = &[1, 2, 4, 8];

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn sleepy_spec(exec_us: u64) -> MockSpec {
    MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(4 * exec_us))
        .with_cost("kern.v1.n8", Duration::from_micros(exec_us))
        .with_sleep_exec()
}

/// Spawn one coordinator per (mode, thread-count) cell. `worker_pool`
/// scales its worker count with the thread count — that is the axis the
/// pool claims to scale along.
fn spawn(mode: &str, threads: usize, exec_us: u64) -> Coordinator {
    let spec = sleepy_spec(exec_us);
    match mode {
        "worker_pool" => {
            spawn_pooled_mock("kern", 2, &[8], spec, threads, ServerOptions::default())
                .expect("spawn pooled coordinator")
        }
        _ => {
            let fast_lane = mode == "fast_lane";
            Coordinator::spawn_with_options(
                move || {
                    let manifest = synthetic_manifest("kern", 2, &[8])?;
                    let registry = KernelRegistry::new(manifest);
                    Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
                },
                ServerOptions { fast_lane, ..ServerOptions::default() },
            )
            .expect("spawn coordinator")
        }
    }
}

/// Tune to steady state, then hammer from `threads` threads; returns
/// steady-state calls/second.
fn measure(coord: &Coordinator, threads: usize, calls_per_thread: usize) -> f64 {
    let h = coord.handle();
    loop {
        let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("warm call");
        if o.route == CallRoute::Tuned {
            break;
        }
    }
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..threads {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..calls_per_thread {
                let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("steady call");
                assert_eq!(o.value, 1, "steady state must serve the winner");
            }
        }));
    }
    for j in joins {
        j.join().expect("worker");
    }
    (threads * calls_per_thread) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    jitune::util::logging::init();
    let calls = env_usize("JITUNE_BENCH_CALLS", 300);
    let exec_us = env_usize("JITUNE_BENCH_EXEC_US", 200) as u64;
    println!(
        "== throughput scaling: tuned calls/sec vs threads ({calls} calls/thread, \
         {exec_us}us exec) =="
    );

    let modes: &[&str] = &["single_lane", "fast_lane", "worker_pool"];
    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut results = Vec::new();
    for &mode in modes {
        let mut points = Vec::new();
        for &threads in THREADS {
            // fresh coordinator per cell: clean tuner, clean stats
            let coord = spawn(mode, threads, exec_us);
            let cps = measure(&coord, threads, calls);
            println!("  {mode:<12} threads={threads}  {cps:10.0} calls/s");
            rows.push(vec![
                mode.to_string(),
                threads.to_string(),
                format!("{cps:.1}"),
            ]);
            points.push((threads as f64, cps));
            results.push(Value::Obj(vec![
                ("mode".into(), s(mode)),
                ("threads".into(), n(threads as f64)),
                ("calls_per_sec".into(), n(cps)),
            ]));
        }
        series.push(Series::new(mode, points));
    }

    let cps_of = |mode: &str, threads: usize| {
        results
            .iter()
            .find(|r| {
                r.get("mode").and_then(Value::as_str) == Some(mode)
                    && r.get("threads").and_then(Value::as_i64) == Some(threads as i64)
            })
            .and_then(|r| r.get("calls_per_sec").and_then(Value::as_f64))
            .unwrap_or(0.0)
    };
    // headline ratios: fast lane / pool vs single lane at each thread count
    let mut speedups = Vec::new();
    for &threads in THREADS {
        let single = cps_of("single_lane", threads);
        let fast = cps_of("fast_lane", threads);
        let pool = cps_of("worker_pool", threads);
        let fast_ratio = if single > 0.0 { fast / single } else { 0.0 };
        let pool_ratio = if single > 0.0 { pool / single } else { 0.0 };
        println!(
            "  speedup at {threads} thread(s): fast lane {fast_ratio:.2}x, \
             worker pool {pool_ratio:.2}x"
        );
        speedups.push(Value::Obj(vec![
            ("threads".into(), n(threads as f64)),
            ("fast_over_single".into(), n(fast_ratio)),
            ("pool_over_single".into(), n(pool_ratio)),
        ]));
    }
    // the ROADMAP scaling claim, measured: pool throughput 1 → 4 workers
    let pool_1 = cps_of("worker_pool", 1);
    let pool_4 = cps_of("worker_pool", 4);
    let pool_scaling = if pool_1 > 0.0 { pool_4 / pool_1 } else { 0.0 };
    println!("  pool scaling 1 -> 4 workers: {pool_scaling:.2}x");

    let fig = Figure {
        stem: "throughput_scaling".into(),
        title: "tuned calls/sec vs application threads (single lane vs fast lane vs pool)"
            .into(),
        header: vec!["mode".into(), "threads".into(), "calls_per_sec".into()],
        rows,
        series,
        log_y: false,
    };
    let rendered = fig.emit().expect("emit");
    println!("{rendered}");

    let report = Value::Obj(vec![
        ("bench".into(), s("throughput_scaling")),
        ("engine".into(), s("mock(sleep)")),
        ("exec_us".into(), n(exec_us as f64)),
        ("calls_per_thread".into(), n(calls as f64)),
        ("results".into(), Value::Arr(results)),
        ("speedups".into(), Value::Arr(speedups)),
        ("pool_scaling_1_to_4".into(), n(pool_scaling)),
    ]);
    jitune::report::write_figure_file("throughput_scaling.json", &report.to_json_pretty())
        .expect("json");
    println!("wrote target/figures/throughput_scaling.{{csv,txt,json}}");
}
