use std::sync::atomic::{AtomicU64, Ordering};

static HITS: AtomicU64 = AtomicU64::new(0);

fn bump() {
    HITS.fetch_add(1, Ordering::Relaxed);
}

fn unresolvable(pairs: &[(String, AtomicU64)]) {
    for (_, v) in pairs {
        v.fetch_add(1, Ordering::Relaxed);
    }
}
