fn spawn_unnamed() {
    std::thread::spawn(|| {});
}
