fn waits_forever(rx: &std::sync::mpsc::Receiver<u32>) -> u32 {
    rx.recv().unwrap_or(0)
}

fn joins_forever(handle: std::thread::JoinHandle<u32>) -> u32 {
    handle.join().unwrap_or(0)
}
