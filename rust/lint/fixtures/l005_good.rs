fn guarded(v: Option<u32>) -> u32 {
    // jitune-lint: allow(L005): the caller checked v above
    v.unwrap()
}

fn guarded_inline(v: Option<u32>) -> u32 {
    v.unwrap() // jitune-lint: allow(L005): same-line form
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(Some(3).unwrap(), 3);
    }
}
