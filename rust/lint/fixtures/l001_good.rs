use crate::sync::{TrackedCondvar, TrackedMutex, TrackedRwLock};

struct Good {
    state: TrackedMutex<u32>,
    map: TrackedRwLock<u32>,
    cv: TrackedCondvar,
}

// Mentioning Mutex, RwLock or Condvar in a comment is fine.
fn sees_strings() -> &'static str {
    "Mutex and Condvar in a string are fine too"
}
