fn sloppy(v: Option<u32>) -> u32 {
    // jitune-lint: allow(L005)
    v.unwrap()
}
