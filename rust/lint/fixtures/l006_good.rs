use std::time::Duration;

fn bounded(rx: &std::sync::mpsc::Receiver<u32>) -> Option<u32> {
    rx.recv_timeout(Duration::from_millis(50)).ok()
}

fn justified(rx: &std::sync::mpsc::Receiver<u32>) -> Option<u32> {
    // jitune-lint: allow(L006): sender drops at shutdown, recv disconnects
    rx.recv().ok()
}

fn justified_inline(handle: std::thread::JoinHandle<u32>) -> u32 {
    handle.join().unwrap_or(0) // jitune-lint: allow(L006): worker loop exits on stop flag
}

fn arg_joins_never_match(parts: &[String], dir: &std::path::Path) -> std::path::PathBuf {
    let _ = parts.join(", ");
    dir.join("sub")
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_block() {
        let (tx, rx) = std::sync::mpsc::channel();
        tx.send(1u32).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
    }
}
