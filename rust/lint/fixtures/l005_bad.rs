fn risky(v: Option<u32>) -> u32 {
    v.unwrap()
}

fn also_risky(v: Option<u32>) -> u32 {
    v.expect("present")
}
