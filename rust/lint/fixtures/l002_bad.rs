fn bad(map: &SomeLock) {
    let _ = map.lock().unwrap();
    let _ = map.read().unwrap();
    let _ = map.write().expect("poisoned");
}
