fn spawn_named() {
    std::thread::Builder::new()
        .name("jitune-worker".into())
        .spawn(|| {})
        .expect("spawn worker");
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_threads_may_be_unnamed() {
        let j = std::thread::spawn(|| 1);
        assert_eq!(j.join().unwrap(), 1);
    }
}
