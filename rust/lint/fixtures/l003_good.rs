use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

static HITS: AtomicU64 = AtomicU64::new(0); // relaxed-counter: stats-only tally

// relaxed-counter: round-robin cursor, no ordering required
static CURSOR: AtomicUsize = AtomicUsize::new(0);

fn bump(buckets: &[AtomicU64]) {
    HITS.fetch_add(1, Ordering::Relaxed);
    CURSOR.fetch_add(1, Ordering::Relaxed);
    for b in buckets {
        b.swap(0, Ordering::Relaxed); // relaxed-counter: draining bucket tallies
    }
}
