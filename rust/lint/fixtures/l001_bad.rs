use std::sync::{Condvar, Mutex, RwLock};

struct Bad {
    state: Mutex<u32>,
    map: RwLock<u32>,
    cv: Condvar,
}
