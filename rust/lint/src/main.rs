//! CLI for jitune-lint: `jitune-lint <path>...` scans every `.rs` file
//! under the given paths and exits non-zero on any finding, so it can be
//! wired straight into CI as a gate.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("usage: jitune-lint <file-or-dir>...");
        return ExitCode::from(2);
    }
    let paths: Vec<PathBuf> = args.iter().map(PathBuf::from).collect();
    match jitune_lint::lint_paths(&paths) {
        Ok(findings) if findings.is_empty() => {
            println!("jitune-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!("jitune-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("jitune-lint: {e}");
            ExitCode::from(2)
        }
    }
}
