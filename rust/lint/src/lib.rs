//! jitune-lint — project-specific concurrency lints for the jitune tree.
//!
//! A deliberately small, std-only pass: a line lexer (tracking block
//! comments, string/raw-string/char literals across lines) feeds six
//! substring-level rules. This is not a parser — the rules are written
//! so that lexical matching is sufficient, and every rule has an inline
//! escape hatch that forces the author to write down *why*.
//!
//! Rules:
//! - **L001** — raw `std::sync` lock types (`Mutex`, `RwLock`, `Condvar`
//!   and their guards) outside `sync/`. Everything else uses the
//!   `crate::sync::Tracked*` wrappers so lock-order tracking and poison
//!   tolerance stay in one place.
//! - **L002** — `.lock().unwrap()` / `.read().unwrap()` /
//!   `.write().unwrap()` (or the `.expect(...)` spellings). Poison
//!   tolerance lives in the wrappers; call sites never re-decide it.
//! - **L003** — `Ordering::Relaxed` on an atomic whose declaration is
//!   not annotated `// relaxed-counter: <why>`. Relaxed is correct only
//!   for pure counters/cursors that never synchronize other memory; the
//!   annotation is the audit trail. When the receiver cannot be
//!   resolved on the usage line (e.g. a loop variable), annotate the
//!   usage line itself.
//! - **L004** — `thread::spawn` outside `#[cfg(test)]`. Production
//!   threads are spawned via `thread::Builder::new().name(..)` so panics,
//!   TSan reports and `/proc` are attributable.
//! - **L005** — `.unwrap()` / `.expect(` on non-test `coordinator/` and
//!   `hub/` paths. Serving-path invariants are either handled or
//!   justified in place.
//! - **L006** — unbounded `.recv()` / `.join()` on non-test
//!   `coordinator/` and `hub/` paths. A serving-path wait with no bound
//!   is a hang waiting for its trigger: use `recv_timeout` (or another
//!   bounded wait), or justify in place why the wait provably
//!   terminates (e.g. the sender's drop disconnects it).
//!
//! Suppression: `// jitune-lint: allow(LXXX): <reason>` on the offending
//! line, or alone on the line directly above it. The reason is
//! mandatory — an allow without one is reported as **L000**.

use std::collections::HashSet;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One lint violation at a file/line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One source line split into executable code and comment text.
struct Line {
    code: String,
    comment: String,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum LexState {
    Code,
    /// Inside a (nesting) block comment, with current depth.
    Block(u32),
    /// Inside a regular string literal.
    Str,
    /// Inside a raw string literal opened with this many `#`s.
    RawStr(u8),
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Split `text` into per-line (code, comment) pairs. String and char
/// literal *contents* are dropped from the code channel (the delimiters
/// are kept) so literals never trip a rule; comment text is preserved
/// separately because annotations and allows live there.
fn lex(text: &str) -> Vec<Line> {
    let mut state = LexState::Code;
    let mut out = Vec::new();
    for raw in text.lines() {
        let b = raw.as_bytes();
        let mut code = Vec::new();
        let mut comment = Vec::new();
        let mut i = 0;
        while i < b.len() {
            match state {
                LexState::Code => {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                        comment.extend_from_slice(&b[i + 2..]);
                        i = b.len();
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        state = LexState::Block(1);
                        code.push(b' ');
                        i += 2;
                    } else if b[i] == b'"' {
                        code.push(b'"');
                        state = LexState::Str;
                        i += 1;
                    } else if b[i] == b'r' && (i == 0 || !is_ident(b[i - 1])) {
                        // raw string head: r" or r#..#"
                        let mut j = i + 1;
                        let mut hashes: u8 = 0;
                        while j < b.len() && b[j] == b'#' {
                            hashes += 1;
                            j += 1;
                        }
                        if j < b.len() && b[j] == b'"' {
                            code.push(b'"');
                            state = LexState::RawStr(hashes);
                            i = j + 1;
                        } else {
                            code.push(b[i]);
                            i += 1;
                        }
                    } else if b[i] == b'\'' {
                        // char literal vs lifetime
                        if i + 1 < b.len() && b[i + 1] == b'\\' {
                            let mut j = i + 2;
                            while j < b.len() && b[j] != b'\'' {
                                j += 1;
                            }
                            i = j + 1;
                        } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                            i += 3;
                        } else {
                            code.push(b'\'');
                            i += 1;
                        }
                    } else {
                        code.push(b[i]);
                        i += 1;
                    }
                }
                LexState::Block(depth) => {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        state = LexState::Block(depth + 1);
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        state = if depth == 1 { LexState::Code } else { LexState::Block(depth - 1) };
                        i += 2;
                    } else {
                        comment.push(b[i]);
                        i += 1;
                    }
                }
                LexState::Str => {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'"' {
                        code.push(b'"');
                        state = LexState::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                LexState::RawStr(hashes) => {
                    if b[i] == b'"' {
                        let mut j = i + 1;
                        let mut seen: u8 = 0;
                        while j < b.len() && seen < hashes && b[j] == b'#' {
                            seen += 1;
                            j += 1;
                        }
                        if seen == hashes {
                            code.push(b'"');
                            state = LexState::Code;
                            i = j;
                        } else {
                            i += 1;
                        }
                    } else {
                        i += 1;
                    }
                }
            }
        }
        out.push(Line {
            code: String::from_utf8_lossy(&code).into_owned(),
            comment: String::from_utf8_lossy(&comment).into_owned(),
        });
    }
    out
}

/// True when `word` occurs in `code` as a whole identifier.
fn has_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let s = from + p;
        let e = s + word.len();
        let pre = s == 0 || !is_ident(b[s - 1]);
        let post = e >= b.len() || !is_ident(b[e]);
        if pre && post {
            return true;
        }
        from = s + 1;
    }
    false
}

/// Name of the atomic declared on this line (`static HITS: AtomicU64`,
/// `executed: AtomicU64,` …): the identifier before the last single `:`
/// preceding the word `Atomic`.
fn counter_decl_name(code: &str) -> Option<String> {
    let b = code.as_bytes();
    let at = code.find("Atomic")?;
    let mut colon = None;
    let mut k = 0;
    while k < at {
        if b[k] == b':' {
            if k + 1 < b.len() && b[k + 1] == b':' {
                k += 2;
                continue;
            }
            colon = Some(k);
        }
        k += 1;
    }
    let c = colon?;
    let mut s = c;
    while s > 0 && is_ident(b[s - 1]) {
        s -= 1;
    }
    if s == c {
        return None;
    }
    Some(code[s..c].to_string())
}

/// Atomic method calls whose last argument is a memory ordering.
const ATOMIC_METHODS: &[&str] = &[
    ".load(",
    ".store(",
    ".swap(",
    ".fetch_add(",
    ".fetch_sub(",
    ".fetch_and(",
    ".fetch_or(",
    ".fetch_xor(",
    ".fetch_max(",
    ".fetch_min(",
    ".compare_exchange(",
    ".compare_exchange_weak(",
];

/// The identifier owning the atomic method call whose `.` is at `dot`:
/// skips trailing index/call brackets, so `shard.buckets[i].fetch_add`
/// resolves to `buckets` — the *field name*, which is what the
/// `relaxed-counter` annotation marks.
fn receiver_before(code: &[u8], dot: usize) -> Option<String> {
    let mut i = dot;
    while i > 0 && (code[i - 1] == b']' || code[i - 1] == b')') {
        let close = code[i - 1];
        let open = if close == b']' { b'[' } else { b'(' };
        let mut depth = 1;
        i -= 1;
        while i > 0 && depth > 0 {
            i -= 1;
            if code[i] == close {
                depth += 1;
            } else if code[i] == open {
                depth -= 1;
            }
        }
        if depth > 0 {
            return None;
        }
    }
    let end = i;
    while i > 0 && is_ident(code[i - 1]) {
        i -= 1;
    }
    if i == end {
        return None;
    }
    String::from_utf8(code[i..end].to_vec()).ok()
}

/// Receivers of every atomic method call on the line, or `None` when the
/// line has no resolvable call (multi-line call, method on another line).
fn relaxed_receivers(code: &str) -> Option<Vec<String>> {
    let mut out = Vec::new();
    for m in ATOMIC_METHODS {
        let mut from = 0;
        while let Some(p) = code[from..].find(m) {
            let dot = from + p;
            out.push(receiver_before(code.as_bytes(), dot)?);
            from = dot + m.len();
        }
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Parse `jitune-lint: allow(LXXX): reason` out of a comment. Returns the
/// rule id and whether a non-empty reason follows.
fn parse_allow(comment: &str) -> Option<(String, bool)> {
    const KEY: &str = "jitune-lint: allow(";
    let p = comment.find(KEY)?;
    let rest = &comment[p + KEY.len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let has_reason = after.starts_with(':') && !after[1..].trim().is_empty();
    Some((rule, has_reason))
}

/// Lock-type identifiers banned outside `sync/` (longest first so the
/// guard names match as their own word, not via their prefix).
const RAW_LOCK_WORDS: &[&str] =
    &["MutexGuard", "RwLockReadGuard", "RwLockWriteGuard", "Mutex", "RwLock", "Condvar"];

const L002_PATTERNS: &[&str] = &[
    ".lock().unwrap()",
    ".read().unwrap()",
    ".write().unwrap()",
    ".lock().expect(",
    ".read().expect(",
    ".write().expect(",
];

/// Unbounded blocking waits banned on serving paths (L006). Exact
/// zero-argument spellings: `.recv_timeout(`, `.join(", ")` and
/// `path.join(x)` carry arguments and never match.
const L006_PATTERNS: &[&str] = &[".recv()", ".join()"];

fn in_dir(path: &str, dir: &str) -> bool {
    path.contains(&format!("/{dir}/")) || path.starts_with(&format!("{dir}/"))
}

/// Run all rules over one file's text. `path` is used both for reporting
/// and for the path-scoped rules (L001 exempts `sync/`, L005 applies to
/// `coordinator/` and `hub/`).
pub fn scan_file(path: &str, text: &str) -> Vec<Finding> {
    let norm = path.replace('\\', "/");
    let in_sync = in_dir(&norm, "sync");
    let coord_or_hub = in_dir(&norm, "coordinator") || in_dir(&norm, "hub");
    let lines = lex(text);
    let n = lines.len();
    let mut findings = Vec::new();

    // Pass 1: relaxed-counter annotations. Collect the set of annotated
    // atomic names and which lines carry a usage-level annotation.
    let mut counters: HashSet<String> = HashSet::new();
    let mut relaxed_allow = vec![false; n];
    let mut pending_ann = false;
    for (i, line) in lines.iter().enumerate() {
        let has_ann = line.comment.contains("relaxed-counter:");
        if line.code.trim().is_empty() {
            pending_ann = pending_ann || has_ann;
            continue;
        }
        if has_ann || pending_ann {
            pending_ann = false;
            relaxed_allow[i] = true;
            if let Some(name) = counter_decl_name(&line.code) {
                counters.insert(name);
            } else if !line.code.contains("Ordering::Relaxed") {
                findings.push(Finding {
                    file: norm.clone(),
                    line: i + 1,
                    rule: "L000",
                    message: "relaxed-counter annotation neither marks an atomic declaration \
                              nor a Relaxed usage"
                        .into(),
                });
            }
        }
    }

    // Pass 2: allow comments.
    let mut allows: Vec<Vec<String>> = vec![Vec::new(); n];
    let mut pending_allows: Vec<String> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if let Some((rule, has_reason)) = parse_allow(&line.comment) {
            if !has_reason {
                findings.push(Finding {
                    file: norm.clone(),
                    line: i + 1,
                    rule: "L000",
                    message: format!("allow({rule}) without a `: <reason>` — say why"),
                });
            }
            if line.code.trim().is_empty() {
                pending_allows.push(rule);
            } else {
                allows[i].push(rule);
            }
        }
        if !line.code.trim().is_empty() && !pending_allows.is_empty() {
            allows[i].append(&mut pending_allows);
        }
    }

    // Pass 3: rules, with `#[cfg(test)]` region tracking by brace depth.
    let mut depth: i64 = 0;
    let mut pending_test = false;
    let mut test_until: Option<i64> = None;
    for (i, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let lineno = i + 1;
        let in_test = test_until.is_some();
        let allowed = |rule: &str| allows[i].iter().any(|r| r == rule);

        if !in_sync && !allowed("L001") {
            if let Some(w) = RAW_LOCK_WORDS.iter().find(|w| has_word(code, w)) {
                findings.push(Finding {
                    file: norm.clone(),
                    line: lineno,
                    rule: "L001",
                    message: format!("raw std::sync `{w}` outside sync/ — use crate::sync::Tracked*"),
                });
            }
        }

        if !allowed("L002") {
            if let Some(p) = L002_PATTERNS.iter().find(|p| code.contains(*p)) {
                findings.push(Finding {
                    file: norm.clone(),
                    line: lineno,
                    rule: "L002",
                    message: format!(
                        "`{p}` — the Tracked* wrappers are poison-tolerant, call `.lock()`/\
                         `.read()`/`.write()` directly"
                    ),
                });
            }
        }

        if code.contains("Ordering::Relaxed") && !relaxed_allow[i] && !allowed("L003") {
            match relaxed_receivers(code) {
                Some(rs) => {
                    if let Some(bad) = rs.iter().find(|r| !counters.contains(*r)) {
                        findings.push(Finding {
                            file: norm.clone(),
                            line: lineno,
                            rule: "L003",
                            message: format!(
                                "`Ordering::Relaxed` on `{bad}`, which is not declared with a \
                                 `// relaxed-counter: <why>` annotation"
                            ),
                        });
                    }
                }
                None => findings.push(Finding {
                    file: norm.clone(),
                    line: lineno,
                    rule: "L003",
                    message: "cannot resolve the atomic behind this `Ordering::Relaxed`; \
                              annotate the line `// relaxed-counter: <why>`"
                        .into(),
                }),
            }
        }

        if !in_test && code.contains("thread::spawn") && !allowed("L004") {
            findings.push(Finding {
                file: norm.clone(),
                line: lineno,
                rule: "L004",
                message: "unnamed `thread::spawn` — production threads use \
                          `thread::Builder::new().name(..)` so panics and TSan reports are \
                          attributable"
                    .into(),
            });
        }

        if coord_or_hub
            && !in_test
            && !allowed("L005")
            && (code.contains(".unwrap()") || code.contains(".expect("))
        {
            findings.push(Finding {
                file: norm.clone(),
                line: lineno,
                rule: "L005",
                message: "`.unwrap()`/`.expect(` on a serving path — handle the error or \
                          justify with `// jitune-lint: allow(L005): <reason>`"
                    .into(),
            });
        }

        if coord_or_hub && !in_test && !allowed("L006") {
            if let Some(p) = L006_PATTERNS.iter().find(|p| code.contains(*p)) {
                findings.push(Finding {
                    file: norm.clone(),
                    line: lineno,
                    rule: "L006",
                    message: format!(
                        "unbounded `{p}` on a serving path — a wait with no bound is a hang \
                         waiting for its trigger; use `recv_timeout`/a bounded wait, or justify \
                         with `// jitune-lint: allow(L006): <reason>`"
                    ),
                });
            }
        }

        // Region bookkeeping runs *after* the rules so the attribute line
        // itself is judged as non-test (it carries no code anyway).
        if code.contains("#[cfg(test)]") {
            if code.contains('{') {
                if test_until.is_none() {
                    test_until = Some(depth);
                }
            } else {
                pending_test = true;
            }
        } else if pending_test
            && !code.trim().is_empty()
            // a stacked attribute keeps us waiting for the actual item
            && !code.trim_start().starts_with("#[")
        {
            if code.contains('{') && test_until.is_none() {
                test_until = Some(depth);
            }
            pending_test = false;
        }
        for ch in code.bytes() {
            match ch {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(d) = test_until {
            if depth <= d {
                test_until = None;
            }
        }
    }

    findings
}

fn collect_rs(p: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if p.is_dir() {
        for entry in fs::read_dir(p)? {
            collect_rs(&entry?.path(), out)?;
        }
    } else if p.extension().is_some_and(|e| e == "rs") {
        out.push(p.to_path_buf());
    }
    Ok(())
}

/// Lint every `.rs` file under the given paths (files or directories),
/// in deterministic path order.
pub fn lint_paths(paths: &[PathBuf]) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let text = fs::read_to_string(&f)?;
        out.extend(scan_file(&f.to_string_lossy(), &text));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(path: &str, text: &str) -> Vec<&'static str> {
        scan_file(path, text).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn l001_fires_on_each_raw_lock_type() {
        let r = rules("coordinator/l001_bad.rs", include_str!("../fixtures/l001_bad.rs"));
        assert_eq!(r.iter().filter(|r| **r == "L001").count(), 4, "{r:?}");
    }

    #[test]
    fn l001_ignores_wrappers_comments_and_strings() {
        let r = rules("coordinator/l001_good.rs", include_str!("../fixtures/l001_good.rs"));
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn l001_exempts_the_sync_module_itself() {
        let r = rules("rust/src/sync/mod.rs", include_str!("../fixtures/l001_bad.rs"));
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn l002_fires_on_poison_unwraps() {
        let r = rules("runtime/l002_bad.rs", include_str!("../fixtures/l002_bad.rs"));
        assert_eq!(r, vec!["L002", "L002", "L002"]);
    }

    #[test]
    fn l003_fires_on_unannotated_relaxed() {
        let r = rules("util/l003_bad.rs", include_str!("../fixtures/l003_bad.rs"));
        assert_eq!(r, vec!["L003", "L003"]);
    }

    #[test]
    fn l003_accepts_all_three_annotation_forms() {
        let r = rules("util/l003_good.rs", include_str!("../fixtures/l003_good.rs"));
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn l003_flags_unresolvable_receivers() {
        let text = "fn f(a: &A) {\n    bump(a).fetch_add(\n        1,\n        Ordering::Relaxed,\n    );\n}\n";
        let r = rules("util/multiline.rs", text);
        assert_eq!(r, vec!["L003"], "ordering on a line without its method call");
    }

    #[test]
    fn l004_fires_outside_tests_only() {
        let bad = rules("runtime/l004_bad.rs", include_str!("../fixtures/l004_bad.rs"));
        assert_eq!(bad, vec!["L004"]);
        let good = rules("runtime/l004_good.rs", include_str!("../fixtures/l004_good.rs"));
        assert!(good.is_empty(), "{good:?}");
    }

    #[test]
    fn l005_fires_on_serving_paths_only() {
        let src = include_str!("../fixtures/l005_bad.rs");
        assert_eq!(rules("coordinator/l005_bad.rs", src), vec!["L005", "L005"]);
        assert_eq!(rules("hub/l005_bad.rs", src), vec!["L005", "L005"]);
        assert!(rules("runtime/l005_bad.rs", src).is_empty(), "only coordinator/ and hub/");
    }

    #[test]
    fn l005_respects_allows_and_test_modules() {
        let r = rules("coordinator/l005_good.rs", include_str!("../fixtures/l005_good.rs"));
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn l006_fires_on_unbounded_serving_waits_only() {
        let src = include_str!("../fixtures/l006_bad.rs");
        assert_eq!(rules("coordinator/l006_bad.rs", src), vec!["L006", "L006"]);
        assert_eq!(rules("hub/l006_bad.rs", src), vec!["L006", "L006"]);
        assert!(rules("runtime/l006_bad.rs", src).is_empty(), "only coordinator/ and hub/");
    }

    #[test]
    fn l006_accepts_bounded_waits_allows_and_arg_joins() {
        let r = rules("coordinator/l006_good.rs", include_str!("../fixtures/l006_good.rs"));
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn allow_without_reason_is_itself_a_finding() {
        let r = rules(
            "coordinator/allow_missing_reason.rs",
            include_str!("../fixtures/allow_missing_reason.rs"),
        );
        assert_eq!(r, vec!["L000"], "suppresses the L005 but reports the naked allow");
    }

    #[test]
    fn literals_never_trip_rules() {
        let text = concat!(
            "fn f() -> &'static str {\n",
            "    let _ = 'x';\n",
            "    let _ = r#\"Mutex .lock().unwrap() thread::spawn\"#;\n",
            "    \"Condvar Ordering::Relaxed .unwrap()\"\n",
            "}\n",
        );
        let r = rules("coordinator/strings.rs", text);
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn block_comments_never_trip_rules() {
        let text = "/* Mutex\n   .lock().unwrap()\n   thread::spawn */\nfn f() {}\n";
        let r = rules("coordinator/blocks.rs", text);
        assert!(r.is_empty(), "{r:?}");
    }

    /// The acceptance gate: the migrated source tree is lint-clean. This
    /// runs in the ordinary workspace test suite, so a regression anywhere
    /// in `rust/src` fails `cargo test` even before the CI lint step.
    #[test]
    fn migrated_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let findings = lint_paths(&[src]).expect("walk rust/src");
        let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(findings.is_empty(), "expected a clean tree:\n{}", report.join("\n"));
    }
}
