//! Quickstart: JIT-autotune the tiled matmul's block size.
//!
//! This is the paper's Listing 6 scenario: a blocked matrix
//! multiplication whose tile size is an `__autotune__` parameter. The
//! first k calls JIT-compile and measure each candidate block size; the
//! winner is then compiled into the instantiation cache and every later
//! call uses it.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

mod common;

use jitune::coordinator::CallRoute;
use jitune::tensor::{ref_matmul, HostTensor};

fn main() {
    jitune::util::logging::init();
    let mut dispatcher = common::dispatcher_or_exit();

    let n = 128usize;
    let a = HostTensor::random(&[n, n], 1);
    let b = HostTensor::random(&[n, n], 2);

    println!("== jitune quickstart: autotuning matmul block size at n={n} ==\n");
    let mut calls = 0;
    loop {
        calls += 1;
        let out = dispatcher.call("matmul_tiled", &[a.clone(), b.clone()]).expect("call");
        println!(
            "call {calls:2}: {:<9} block={:<4} compile={:<5} {:7.2}ms",
            format!("{:?}", out.route).to_lowercase(),
            out.value,
            out.compiled,
            out.total.as_secs_f64() * 1e3
        );
        if out.route == CallRoute::Finalized {
            break;
        }
    }

    let tuned = dispatcher.tuned_value("matmul_tiled", n as i64).expect("tuned");
    println!("\ntuned block size: {tuned}");

    // steady state: a few more calls through the cached winner
    let mut steady = Vec::new();
    let mut last = None;
    for _ in 0..5 {
        let out = dispatcher.call("matmul_tiled", &[a.clone(), b.clone()]).expect("call");
        assert_eq!(out.route, CallRoute::Tuned);
        steady.push(out.total.as_secs_f64() * 1e3);
        last = Some(out.output);
    }
    println!(
        "steady-state calls: {:?} ms",
        steady.iter().map(|t| format!("{t:.2}")).collect::<Vec<_>>()
    );

    // verify against the pure-Rust reference
    let want = ref_matmul(&a, &b).expect("ref");
    let got = last.unwrap();
    assert!(got.allclose(&want, 1e-4, 1e-4), "kernel output diverges from reference!");
    println!("result verified against pure-Rust reference ✓");

    print!("\n{}", dispatcher.stats().render());
    println!("cache: {:?}", dispatcher.cache_stats());
}
