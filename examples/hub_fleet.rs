//! Fleet warm-start through the tuned-state hub — self-contained demo
//! on the mock engine (no artifacts or PJRT needed, runs anywhere).
//!
//! An in-process broker ([`HubServer`]) stands in for
//! `jitune hub serve --socket <path>`; two coordinators stand in for two
//! serving processes. Process A tunes a kernel online and publishes the
//! winner at finalization; process B spawns with
//! `ServerOptions { hub: Some(..) }` and warm-starts off the broker —
//! its very first call pays only the winner's final compilation, with
//! **zero explore iterations**. A retune in process A (here: manual,
//! after a latency fault on the winner — a drift policy triggers the
//! same path automatically) publishes a new version, and process B
//! adopts it on its next pull. (The fault is 20x so the degraded winner
//! is decisively slower than the alternative and the rematch flips.)
//!
//! Run with: `cargo run --example hub_fleet [-- --smoke]`
//! (`--smoke` skips the serving pauses for CI; the assertions are
//! identical and a broken warm-start path fails the run.)

use std::path::Path;
use std::time::Duration;

use jitune::coordinator::{
    CallRoute, Coordinator, CoordinatorHandle, Dispatcher, KernelRegistry, ServerOptions,
};
use jitune::hub::{HubOptions, HubServer};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

fn call(h: &CoordinatorHandle) -> jitune::coordinator::CallOutcome {
    h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call")
}

/// Spawn one "serving process": a mock-backed coordinator joined to the
/// broker at `socket`.
fn spawn_member(name: &'static str, socket: &Path, spec: MockSpec) -> Coordinator {
    let hub = HubOptions { peer: name.into(), ..HubOptions::at(socket) };
    Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { hub: Some(hub), ..ServerOptions::default() },
    )
    .expect("spawn coordinator")
}

fn explored_count(h: &CoordinatorHandle) -> i64 {
    h.stats_json()
        .expect("stats_json")
        .get("kernels")
        .and_then(|k| k.get("kern"))
        .and_then(|k| k.get("explored"))
        .and_then(jitune::util::json::Value::as_i64)
        .unwrap_or(0)
}

fn fail(msg: &str) -> ! {
    eprintln!("ERROR: {msg}");
    std::process::exit(1);
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");

    let socket = jitune::testutil::temp_path("hub-fleet", "sock");
    HubServer::bind(&socket).expect("bind hub").spawn();
    println!("hub broker listening on {}\n", socket.display());

    // v1 wins the first tune; the fault handle lets us degrade it later
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(600))
        .with_cost("kern.v1.n8", Duration::from_micros(60));
    let fault = spec.latency_fault.clone();

    println!("process A: tuning from scratch...");
    let a = spawn_member("process-a", &socket, spec.clone());
    let ha = a.handle();
    loop {
        let o = call(&ha);
        println!("  {:?} variant={} value={}", o.route, o.variant_id, o.value);
        if o.route == CallRoute::Finalized {
            break;
        }
    }
    println!(
        "process A tuned value: {:?} ({} explore iterations) — winner published to hub\n",
        ha.tuned_value("kern", 8).expect("tuned_value"),
        explored_count(&ha)
    );

    println!("process B: cold start against a warm hub...");
    let b = spawn_member("process-b", &socket, spec);
    let hb = b.handle();
    let first = call(&hb);
    println!("  first call: {:?} value={}", first.route, first.value);
    if first.route != CallRoute::Finalized || explored_count(&hb) != 0 {
        fail("warm start must skip exploration entirely");
    }
    println!("process B warm-started with ZERO explore iterations\n");

    if !smoke {
        // a little steady-state serving on both members
        for _ in 0..200 {
            call(&ha);
            call(&hb);
        }
    }

    println!("injecting 20x latency shift into the winner, retuning in process A...");
    fault.set_scale("kern.v1.n8", 20.0);
    ha.retune("kern", 8).expect("retune");
    loop {
        if call(&ha).route == CallRoute::Finalized {
            break;
        }
    }
    let new_winner = ha.tuned_value("kern", 8).expect("tuned_value");
    println!("process A retuned value: {new_winner:?} — published at the next version\n");
    if new_winner != Some(0) {
        fail("rematch under the fault must flip the winner");
    }

    println!("process B: pulling the hub to adopt the retuned winner...");
    let (adopted, _skipped) = hb.hub_pull().expect("hub_pull");
    let o = call(&hb);
    println!("  adopted {adopted} entr(ies); next call: {:?} value={}", o.route, o.value);
    if adopted != 1 || o.value != 0 {
        fail("process B must adopt the retuned winner on its next pull");
    }

    for (name, h) in [("A", &ha), ("B", &hb)] {
        let json = h.stats_json().expect("stats_json");
        if let Some(hub) = json.get("hub") {
            println!("process {name} hub stats: {}", hub.to_json());
        }
    }
    println!("\nfleet warm-start demo complete");
    let _ = std::fs::remove_file(&socket);
}
