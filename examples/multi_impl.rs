//! Choosing between implementations — the paper's Listing 5 scenario.
//!
//! Three matmul implementations differing only in loop order (ijk, ikj,
//! jik) compete; the autotuner plays the paper's proxy function, trying
//! each on the first calls and routing every later call to the winner.
//! The example then compares the autotuned service against each fixed
//! implementation over the same workload (a miniature Fig 3/4/5).
//!
//! Run: `cargo run --release --example multi_impl`

mod common;

use jitune::baseline::FixedVariant;
use jitune::manifest::Manifest;
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::tensor::HostTensor;

fn main() {
    jitune::util::logging::init();
    let mut dispatcher = common::dispatcher_or_exit();

    let n = 128usize;
    let iters = 40;
    let a = HostTensor::random(&[n, n], 7);
    let b = HostTensor::random(&[n, n], 8);
    let inputs = [a, b];

    println!("== choosing between implementations (ijk / ikj / jik) at n={n} ==\n");

    // -- autotuned service ------------------------------------------------
    let mut cumulative = 0.0;
    for i in 0..iters {
        let out = dispatcher.call("matmul_order", &inputs).expect("call");
        cumulative += out.total.as_secs_f64();
        if i < 6 {
            println!(
                "call {i:2}: {:<9} order={:<4} {:7.2}ms (cumulative {:7.2}ms)",
                format!("{:?}", out.route).to_lowercase(),
                out.variant_id.split('.').nth(1).unwrap_or("?"),
                out.total.as_secs_f64() * 1e3,
                cumulative * 1e3
            );
        }
    }
    let auto_total = cumulative;
    let winner = dispatcher.tuned_value("matmul_order", n as i64);
    println!("...\nautotuned total over {iters} calls: {:.1}ms (winner index {winner:?})\n", auto_total * 1e3);

    // -- fixed baselines ---------------------------------------------------
    let manifest = Manifest::load(common::artifacts_dir()).expect("manifest");
    let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
    let problem = manifest.problem("matmul_order", n as i64).expect("problem").clone();
    println!("fixed baselines (compile cost paid ahead of time):");
    for (idx, v) in problem.variants.iter().enumerate() {
        let run = FixedVariant::run(&manifest, &mut cache, &problem, idx, &inputs, iters)
            .expect("baseline");
        println!(
            "  {:<10} total={:8.1}ms  (setup {:6.1}ms, mean call {:6.2}ms)",
            v.label,
            run.total() * 1e3,
            run.setup.as_secs_f64() * 1e3,
            run.total() / iters as f64 * 1e3
        );
    }
    println!(
        "\nautotuned pays the tuning overhead once, then tracks the best \
         implementation — with enough calls it beats any wrong fixed choice."
    );
}
