//! Drift-triggered automatic retuning — self-contained demo on the mock
//! engine (no artifacts or PJRT needed, runs anywhere).
//!
//! The coordinator tunes a kernel online and serves it from the fast
//! lane; mid-run the winning variant's latency is degraded 10x (the
//! mock's `LatencyFault` models thermal throttling / co-tenancy / input
//! shift). With `ServerOptions { drift: Some(policy) }` the leader
//! notices the windowed latency regression against the tuning-time
//! baseline and re-opens tuning **without any `retune()` call**; the
//! rematch picks the variant that is now fastest and serving resumes.
//!
//! Run with: `cargo run --example drift_retune [--smoke]`
//! (`--smoke` shortens every phase for CI.)

use std::time::{Duration, Instant};

use jitune::coordinator::{
    CallRoute, Coordinator, CoordinatorHandle, Dispatcher, DriftPolicy, KernelRegistry,
    ServerOptions,
};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

fn call(h: &CoordinatorHandle) -> jitune::coordinator::CallOutcome {
    h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call")
}

/// Serve steadily for `ms` milliseconds; returns (calls, mean latency ms).
fn serve(h: &CoordinatorHandle, ms: u64) -> (usize, f64) {
    let t0 = Instant::now();
    let mut calls = 0usize;
    while t0.elapsed() < Duration::from_millis(ms) {
        call(h);
        calls += 1;
    }
    let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / calls.max(1) as f64;
    (calls, mean_ms)
}

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    // slow_us sits between 1x and 10x of fast_us: after the 10x shift the
    // degraded winner is decisively slower than the alternative, so the
    // rematch flips the winner instead of re-picking it.
    let (phase_ms, fast_us, slow_us) = if smoke { (300, 80, 300) } else { (1500, 200, 600) };

    // v1 wins tuning; sleep-based execution models an
    // accelerator-offloaded kernel.
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(slow_us))
        .with_cost("kern.v1.n8", Duration::from_micros(fast_us))
        .with_sleep_exec();
    let fault = spec.latency_fault.clone();
    let policy = DriftPolicy {
        window: Duration::from_millis(100),
        min_samples: 10,
        ratio_threshold: 2.0,
        cooldown: Duration::from_millis(200),
        consecutive_windows: 2,
        ..DriftPolicy::default()
    };
    let coordinator = Coordinator::spawn_with_options(
        move || {
            let manifest = synthetic_manifest("kern", 2, &[8])?;
            let registry = KernelRegistry::new(manifest);
            Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
        },
        ServerOptions { drift: Some(policy), ..ServerOptions::default() },
    )
    .expect("spawn coordinator");
    let h = coordinator.handle();

    println!("tuning...");
    loop {
        let o = call(&h);
        println!("  {:?} variant={} value={}", o.route, o.variant_id, o.value);
        if o.route == CallRoute::Finalized {
            break;
        }
    }
    println!("tuned value: {:?}\n", h.tuned_value("kern", 8).expect("tuned_value"));

    let (calls, mean_ms) = serve(&h, phase_ms);
    println!("healthy serving: {calls} calls, mean {mean_ms:.3}ms/call");

    println!("\ninjecting 10x latency shift into the winner (thermal throttle)...");
    fault.set_scale("kern.v1.n8", 10.0);

    // Serve through the degradation: the drift policy must notice and
    // re-open tuning on its own.
    let t0 = Instant::now();
    let mut detected = None;
    while detected.is_none() {
        let o = call(&h);
        if o.route == CallRoute::Explored {
            detected = Some(t0.elapsed());
        }
        if t0.elapsed() > Duration::from_secs(60) {
            break;
        }
    }
    match detected {
        Some(dt) => println!(
            "drift detected: automatic retune began {:.0}ms after the shift",
            dt.as_secs_f64() * 1e3
        ),
        None => {
            // CI runs this example in smoke mode as a regression check:
            // a missing retune must fail the step, not just log.
            eprintln!("ERROR: no automatic retune observed within 60s");
            std::process::exit(1);
        }
    }
    // let the rematch finish
    loop {
        if call(&h).route == CallRoute::Tuned {
            break;
        }
    }
    println!("new tuned value: {:?}", h.tuned_value("kern", 8).expect("tuned_value"));

    let (calls, mean_ms) = serve(&h, phase_ms);
    println!("recovered serving: {calls} calls, mean {mean_ms:.3}ms/call\n");

    let (rendered, _report) = h.stats().expect("stats");
    println!("{rendered}");
    let json = h.stats_json().expect("stats_json");
    if let Some(events) = json.get("drift_events") {
        println!("drift_events: {}", events.to_json_pretty());
    }
}
