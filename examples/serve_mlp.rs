//! End-to-end driver: a batched MLP inference service with JIT
//! autotuning on the request path.
//!
//! A small real model (256→512→256 MLP block, f32, batch 64 — both
//! matmuls run through the tiled Pallas kernel) is served by the
//! threaded coordinator. Four client threads submit batched inference
//! requests; the first requests are tuning iterations (JIT compile +
//! measure per block-size candidate), after which the service settles on
//! the tuned variant. The run reports the latency distribution and
//! throughput of the tuned steady state versus the tuning warm-up, and
//! verifies outputs against the pure-Rust reference.
//!
//! This exercises every layer: Pallas kernel (L1) → lowered JAX model
//! (L2) → manifest → PJRT JIT compile cache → autotuner → threaded
//! coordinator (L3).
//!
//! Run: `cargo run --release --example serve_mlp`

mod common;

use std::time::Instant;

use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry};
use jitune::manifest::Manifest;
use jitune::runtime::PjrtEngine;
use jitune::tensor::{ref_mlp_block, HostTensor};
use jitune::util::hist::Histogram;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 50;

fn main() {
    jitune::util::logging::init();
    let artifacts = common::artifacts_dir();

    let coordinator = Coordinator::spawn(move || {
        let manifest = Manifest::load(&artifacts)?;
        let registry = KernelRegistry::new(manifest);
        let engine = PjrtEngine::cpu()?;
        Ok(Dispatcher::new(registry, Box::new(engine)))
    })
    .expect("coordinator");

    // model inputs: activations vary per request, weights are fixed
    let (b, d, h, o) = (64usize, 256usize, 512usize, 256usize);
    let w1 = HostTensor::random(&[d, h], 1001);
    let w2 = HostTensor::random(&[h, o], 1002);

    println!(
        "== serving mlp_block ({b}x{d} -> {h} -> {o}) with {CLIENTS} clients x {REQUESTS_PER_CLIENT} requests =="
    );

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for client in 0..CLIENTS {
        let handle = coordinator.handle();
        let (w1, w2) = (w1.clone(), w2.clone());
        joins.push(std::thread::spawn(move || {
            let mut warmup = Histogram::latency();
            let mut steady = Histogram::latency();
            let mut verified = false;
            for req in 0..REQUESTS_PER_CLIENT {
                let x = HostTensor::random(&[b, d], 7 + (client * REQUESTS_PER_CLIENT + req) as u64);
                let t = Instant::now();
                let out = handle
                    .call("mlp_block", vec![x.clone(), w1.clone(), w2.clone()])
                    .expect("request");
                let dt = t.elapsed().as_secs_f64();
                match out.route {
                    CallRoute::Tuned => steady.record(dt),
                    _ => warmup.record(dt),
                }
                // verify one response per client against the Rust oracle
                if !verified && out.route == CallRoute::Tuned {
                    let want = ref_mlp_block(&x, &w1, &w2).expect("ref");
                    assert!(
                        out.output.allclose(&want, 5e-3, 5e-3),
                        "client {client}: served output diverges from reference"
                    );
                    verified = true;
                }
            }
            assert!(verified, "client {client} never saw a tuned response");
            (warmup, steady)
        }));
    }

    let mut warmup = Histogram::latency();
    let mut steady = Histogram::latency();
    for j in joins {
        let (w, s) = j.join().expect("client thread");
        warmup.merge(&w);
        steady.merge(&s);
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (CLIENTS * REQUESTS_PER_CLIENT) as f64;

    println!("\nall outputs verified against pure-Rust reference ✓");
    println!("\nwarm-up (tuning) requests: {}", warmup.render_ms());
    println!("steady-state requests:     {}", steady.render_ms());
    println!(
        "\nthroughput: {:.1} req/s overall ({:.0} requests in {:.2}s wall)",
        total / wall,
        total,
        wall
    );
    println!(
        "steady-state throughput bound: {:.1} req/s (1/mean latency, single PJRT stream)",
        1.0 / steady.mean().max(1e-12)
    );

    let tuned = coordinator.handle().tuned_value("mlp_block", b as i64).expect("rpc");
    println!("\ntuned block size for the whole MLP block: {tuned:?}");
    let (stats, _report) = coordinator.handle().stats().expect("stats");
    print!("\n{stats}");
}
