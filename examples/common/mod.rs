//! Shared helpers for the examples: locate artifacts, build a dispatcher.
#![allow(dead_code)] // each example uses a subset of these helpers

use jitune::coordinator::{Dispatcher, KernelRegistry};
use jitune::manifest::Manifest;
use jitune::runtime::PjrtEngine;
use jitune::Result;

/// Artifacts directory (env `JITUNE_ARTIFACTS` or `./artifacts`).
pub fn artifacts_dir() -> String {
    std::env::var("JITUNE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Build a PJRT-backed dispatcher with the paper's defaults, or exit
/// with a helpful message when artifacts are missing.
pub fn dispatcher_or_exit() -> Dispatcher {
    match try_dispatcher() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    }
}

fn try_dispatcher() -> Result<Dispatcher> {
    let manifest = Manifest::load(artifacts_dir())?;
    let registry = KernelRegistry::new(manifest);
    let engine = PjrtEngine::cpu()?;
    Ok(Dispatcher::new(registry, Box::new(engine)))
}
