//! Application-portfolio proxy (paper §5): a 1-D explicit heat-diffusion
//! solver whose inner kernel is the autotuned Jacobi stencil, plus a
//! saxpy-based residual damping step — two kernels, both JIT-autotuned
//! *inside the running application*, with zero tuning-specific code in
//! the solver loop.
//!
//! This is the paper's closing argument (the SW4lite/LULESH perspective):
//! performance portability with no invasive modification — the solver
//! below is written as if autotuning did not exist; the runtime tunes
//! under it during the first timesteps.
//!
//! Run: `cargo run --release --example heat_solver`

mod common;

use jitune::coordinator::CallRoute;
use jitune::tensor::{ref_saxpy, ref_stencil3, HostTensor};

const N: usize = 16384;
const STEPS: usize = 30;

fn main() {
    jitune::util::logging::init();
    let mut dispatcher = common::dispatcher_or_exit();

    // initial condition: a hot spike in the middle of a cold rod
    let mut u = HostTensor::zeros(&[N]);
    u.data_mut()[N / 2] = 1000.0;
    let cooling = HostTensor::full(&[N], 0.0); // ambient term for the saxpy
    let alpha = HostTensor::from_vec(&[1], vec![0.98]).unwrap(); // damping

    println!("== heat diffusion on a {N}-cell rod, {STEPS} explicit steps ==");
    println!("(stencil + saxpy both JIT-autotuned under the solver)\n");

    let t0 = std::time::Instant::now();
    let mut tuning_calls = 0;
    for step in 0..STEPS {
        // diffusion: u <- 3-point Jacobi average (autotuned stencil)
        let out = dispatcher.call("stencil", std::slice::from_ref(&u)).expect("stencil");
        if out.route != CallRoute::Tuned {
            tuning_calls += 1;
        }
        // damping: u <- alpha*u + ambient (autotuned saxpy)
        let damped = dispatcher
            .call("saxpy", &[alpha.clone(), out.output.clone(), cooling.clone()])
            .expect("saxpy");
        if damped.route != CallRoute::Tuned {
            tuning_calls += 1;
        }
        u = damped.output;
        if step % 10 == 0 || step == STEPS - 1 {
            let peak = u.data().iter().cloned().fold(f32::MIN, f32::max);
            let total: f32 = u.data().iter().sum();
            println!("step {step:3}: peak={peak:9.3}  total heat={total:9.2}");
        }
    }
    let wall = t0.elapsed();

    // physics sanity: diffusion spreads and damping dissipates
    let peak = u.data().iter().cloned().fold(f32::MIN, f32::max);
    assert!(peak < 1000.0, "heat must diffuse");
    assert!(peak > 0.0);

    // cross-check the final state against the pure-Rust references
    let mut check = HostTensor::zeros(&[N]);
    check.data_mut()[N / 2] = 1000.0;
    for _ in 0..STEPS {
        check = ref_saxpy(0.98, &ref_stencil3(&check).unwrap(), &cooling).unwrap();
    }
    assert!(
        u.allclose(&check, 1e-4, 1e-4),
        "solver state diverged from reference (max diff {:?})",
        u.max_abs_diff(&check)
    );
    println!("\nfinal state verified against pure-Rust reference ✓");

    println!(
        "\n{} solver steps in {:.2}s — {tuning_calls} of {} kernel calls were tuning iterations;\n\
         the solver loop contains no tuning code (the paper's §5 portability goal).",
        STEPS,
        wall.as_secs_f64(),
        2 * STEPS
    );
    println!(
        "tuned: stencil block={:?}, saxpy chunk={:?}",
        dispatcher.tuned_value("stencil", N as i64),
        dispatcher.tuned_value("saxpy", N as i64)
    );
    print!("\n{}", dispatcher.stats().render());
}
