//! Multi-threaded serving on the worker pool — self-contained demo on
//! the mock engine *forced thread-pinned* (every kernel refuses
//! `shared()`, the PJRT shape), so tuned calls cannot take the shared
//! fast lane. Instead, a pool of workers — each owning its own engine —
//! replays the finalized winner from private caches and serves tuned
//! calls from a sharded queue. Compare with `fast_lane_serving`, where
//! the engine shares executables and callers run them in-place.
//!
//! The coordinator tunes the kernel online (exploration serialized on
//! the leader thread), broadcasts the winner to every worker (replicated
//! finalization: one compile per worker), and then N application threads
//! hammer the tuned kernel through the pool.
//!
//! Run with: `cargo run --example pool_serving [threads] [--smoke]`
//! (`--smoke` shortens the run for CI.)

use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, ServerOptions};
use jitune::runtime::mock::MockSpec;
use jitune::tensor::HostTensor;
use jitune::testutil::spawn_pooled_mock;

fn main() {
    jitune::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(4);
    let calls_per_thread: usize = if smoke { 50 } else { 400 };

    // Three candidate variants; v1 is 10x faster. Sleep-based execution
    // models a kernel offloaded to an accelerator.
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(2000))
        .with_cost("kern.v1.n8", Duration::from_micros(200))
        .with_cost("kern.v2.n8", Duration::from_micros(1500))
        .with_sleep_exec();
    let workers = threads;
    let coordinator = spawn_pooled_mock("kern", 3, &[8], spec, workers, ServerOptions::default())
        .expect("spawn pooled coordinator");

    // Phase 1: online tuning (leader lane, serialized).
    let h = coordinator.handle();
    println!("tuning...");
    loop {
        let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call");
        println!("  {:?} variant={} value={}", o.route, o.variant_id, o.value);
        if o.route == CallRoute::Finalized {
            break;
        }
    }
    println!(
        "tuned value: {:?}; fast-lane entries: {} (pool-routed; kernels are thread-pinned)",
        h.tuned_value("kern", 8).expect("tuned_value"),
        h.fast_lane_published()
    );
    assert_eq!(h.fast_lane_published(), 1, "winner replicated onto the pool");

    // Phase 2: steady-state serving from many threads via the pool.
    println!("\nserving from {threads} thread(s) on {workers} pool worker(s), \
              {calls_per_thread} calls each...");
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = coordinator.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..calls_per_thread {
                let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("steady call");
                assert_eq!(o.route, CallRoute::Tuned);
                assert_eq!(o.value, 1);
            }
            t
        }));
    }
    for j in joins {
        j.join().expect("worker");
    }
    let dt = t0.elapsed();
    let total = threads * calls_per_thread;
    println!(
        "served {total} calls in {:.3}s -> {:.0} calls/s across {threads} thread(s)",
        dt.as_secs_f64(),
        total as f64 / dt.as_secs_f64()
    );

    let snap = h.pool_snapshot().expect("pool attached");
    for (idx, w) in snap.workers.iter().enumerate() {
        println!(
            "pool worker {idx}: executed={} compiles={} mean={:.3}ms",
            w.executed,
            w.compiles,
            w.mean_exec_s * 1e3
        );
    }
    // CI runs this example in smoke mode as a regression check: every
    // tuned call above was served by a pool worker, none by the leader.
    assert_eq!(
        snap.total_executed(),
        total as u64,
        "all steady-state calls ran on pool workers"
    );
    let (rendered, _report) = h.stats().expect("stats");
    println!("\n{rendered}");
}
