//! Parameter reuse across kernels — the paper's §3.2 workflow.
//!
//! "If the programmer decides that this block size can be used by other
//! computation routines, they can define these routines as JIT-compiled
//! templates and pass it as a non-type template parameter."
//!
//! We tune the tiled matmul's block size, read the winner back through
//! the public API, and use it to *select* (not re-tune) the stencil
//! kernel's block variant — skipping that kernel's tuning iterations
//! entirely. The example then verifies the reused choice against what a
//! from-scratch tuning of the stencil would have picked.
//!
//! Run: `cargo run --release --example param_reuse`

mod common;

use jitune::coordinator::CallRoute;
use jitune::manifest::Manifest;
use jitune::runtime::{CompileCache, PjrtEngine};
use jitune::tensor::HostTensor;
use jitune::workload::inputs_for;

fn main() {
    jitune::util::logging::init();
    let mut dispatcher = common::dispatcher_or_exit();

    // -- 1. tune the matmul block size -------------------------------------
    let n = 256usize;
    let inputs = {
        let p = dispatcher.registry().problem("matmul_tiled", n as i64).expect("problem").clone();
        inputs_for(&p, 99)
    };
    println!("== tuning matmul_tiled at n={n} ==");
    loop {
        let out = dispatcher.call("matmul_tiled", &inputs).expect("call");
        if out.route == CallRoute::Finalized {
            break;
        }
    }
    let block = dispatcher.tuned_value("matmul_tiled", n as i64).expect("tuned");
    println!("matmul's tuned block size: {block}\n");

    // -- 2. reuse it for the stencil kernel --------------------------------
    // The stencil's candidates are {256, 1024, 4096}; reuse picks the
    // candidate closest to the matmul's winner (the paper hands the raw
    // value to the next template — our variant set is discrete).
    let manifest = Manifest::load(common::artifacts_dir()).expect("manifest");
    let sn = 16384i64;
    let stencil = manifest.problem("stencil", sn).expect("stencil").clone();
    let reused = stencil
        .variants
        .iter()
        .min_by_key(|v| (v.value - block).abs())
        .expect("variants");
    println!(
        "== reusing block={} for the stencil (picked candidate {}) — no tuning iterations ==",
        block, reused.label
    );
    let mut cache = CompileCache::new(Box::new(PjrtEngine::cpu().expect("pjrt")));
    let sten_inputs = vec![HostTensor::random(&[sn as usize], 5)];
    let (exe, _) = cache.get_or_compile(&manifest, reused).expect("compile");
    let t0 = std::time::Instant::now();
    for _ in 0..10 {
        exe.execute(&sten_inputs).expect("execute");
    }
    let reuse_mean = t0.elapsed().as_secs_f64() / 10.0;
    println!("stencil with reused block: mean {:.3}ms/call over 10 calls\n", reuse_mean * 1e3);

    // -- 3. compare with tuning the stencil from scratch -------------------
    println!("== counterfactual: tuning the stencil from scratch ==");
    loop {
        let out = dispatcher.call("stencil", &sten_inputs).expect("call");
        if out.route == CallRoute::Finalized {
            break;
        }
    }
    let tuned_block = dispatcher.tuned_value("stencil", sn).expect("tuned");
    println!("stencil's own tuned block: {tuned_block} (reused pick was {})", reused.value);
    let explored = dispatcher.stats().kernel("stencil").map(|k| k.explored).unwrap_or(0);
    println!(
        "\nreuse skipped {explored} tuning iterations (each paying a JIT compile); \
         the paper's point: the tuned parameter is a first-class value the \
         programmer can route to other kernels."
    );
}
