//! Multi-threaded serving on the tuned fast lane — self-contained demo
//! on the mock engine (no artifacts or PJRT needed, runs anywhere).
//!
//! The coordinator tunes a kernel online (exploration serialized on the
//! leader thread), publishes the winner into the fast lane, and then N
//! application threads hammer the tuned kernel: each call executes on
//! the calling thread, so throughput scales with the threads instead of
//! being capped by the leader. Compare with `serve_mlp`, the PJRT-backed
//! serving demo, where every call flows through the leader.
//!
//! Run with: `cargo run --example fast_lane_serving [threads]`

use std::time::{Duration, Instant};

use jitune::coordinator::{CallRoute, Coordinator, Dispatcher, KernelRegistry};
use jitune::runtime::mock::{MockEngine, MockSpec};
use jitune::tensor::HostTensor;
use jitune::testutil::synthetic_manifest;

const CALLS_PER_THREAD: usize = 400;

fn main() {
    jitune::util::logging::init();
    let threads: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4);

    // Three candidate variants; v1 is 10x faster. Sleep-based execution
    // models a kernel offloaded to an accelerator.
    let spec = MockSpec::default()
        .with_cost("kern.v0.n8", Duration::from_micros(2000))
        .with_cost("kern.v1.n8", Duration::from_micros(200))
        .with_cost("kern.v2.n8", Duration::from_micros(1500))
        .with_sleep_exec();
    let coordinator = Coordinator::spawn(move || {
        let manifest = synthetic_manifest("kern", 3, &[8])?;
        let registry = KernelRegistry::new(manifest);
        Ok(Dispatcher::new(registry, Box::new(MockEngine::new(spec))))
    })
    .expect("spawn coordinator");

    // Phase 1: online tuning (leader lane, serialized).
    let h = coordinator.handle();
    println!("tuning...");
    loop {
        let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("call");
        println!("  {:?} variant={} value={}", o.route, o.variant_id, o.value);
        if o.route == CallRoute::Finalized {
            break;
        }
    }
    println!(
        "tuned value: {:?}; fast-lane entries: {}",
        h.tuned_value("kern", 8).expect("tuned_value"),
        h.fast_lane_published()
    );

    // Phase 2: steady-state serving from many threads (fast lane).
    println!("\nserving from {threads} thread(s), {CALLS_PER_THREAD} calls each...");
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = coordinator.handle();
        joins.push(std::thread::spawn(move || {
            for _ in 0..CALLS_PER_THREAD {
                let o = h.call("kern", vec![HostTensor::zeros(&[8, 8])]).expect("steady call");
                assert_eq!(o.route, CallRoute::Tuned);
            }
            t
        }));
    }
    for j in joins {
        j.join().expect("worker");
    }
    let dt = t0.elapsed();
    let total = threads * CALLS_PER_THREAD;
    println!(
        "served {total} calls in {:.3}s -> {:.0} calls/s across {threads} thread(s)",
        dt.as_secs_f64(),
        total as f64 / dt.as_secs_f64()
    );

    for (kernel, hits, mean) in h.fast_lane_stats() {
        println!("fast lane: {kernel}: hits={hits} mean={:.3}ms", mean * 1e3);
    }
    let (rendered, _report) = h.stats().expect("stats");
    println!("\n{rendered}");
}
